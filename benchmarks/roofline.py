"""Benchmark: roofline table from the dry-run artifacts (assignment §g).

Reads results/dryrun/*.json written by ``repro.launch.dryrun`` and prints the
per-(arch × shape × mesh) three-term roofline with bottleneck + MFU-style
fraction. Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import glob
import json
import os
import time

DRYRUN_DIR = os.environ.get("KOTTA_DRYRUN_DIR", "results/dryrun")


def load(dryrun_dir: str = DRYRUN_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(verbose: bool = True):
    t0 = time.perf_counter()
    cells = load()
    base = [c for c in cells if not c.get("config_overrides")
            and c.get("microbatches", 1) == 1 and not c.get("rule_overrides")]
    ok = [c for c in base if c.get("status") == "ok"]
    if not cells:
        print("(no dry-run artifacts found — run repro.launch.dryrun --all)")
        return [("roofline.cells", 0.0, "missing")]
    if verbose:
        print("\n== Roofline (single-pod baselines; terms in seconds/step) ==")
        print(f"{'arch':<18}{'shape':<12}{'mesh':<7}{'compute':>9}"
              f"{'memory':>9}{'mem.fus':>9}{'collect':>9} {'bottleneck':<12}"
              f"{'useful':>7}{'frac':>7}{'fits':>5}")
        for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
            r = c["roofline"]
            print(f"{c['arch']:<18}{c['shape']:<12}{c['mesh']:<7}"
                  f"{r['compute_s']:>9.2e}{r['memory_s']:>9.2e}"
                  f"{r.get('memory_fused_s', 0):>9.2e}"
                  f"{r['collective_s']:>9.2e} "
                  f"{r['bottleneck'].replace('_s', ''):<12}"
                  f"{r['useful_flops_ratio']:>7.2f}"
                  f"{r['roofline_fraction']:>7.3f}"
                  f"{'y' if c['memory']['fits_hbm'] else 'N':>5}")
        skipped = [c for c in base if c.get("status") == "skipped"]
        for c in sorted(skipped, key=lambda c: (c["arch"], c["shape"])):
            print(f"{c['arch']:<18}{c['shape']:<12}{c['mesh']:<7} "
                  f"SKIP: {c['reason']}")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    multi = [c for c in base if c.get("status") == "ok" and c["mesh"] == "multi"]
    return [("roofline.cells_ok", elapsed_us, f"ok={len(ok)}"),
            ("roofline.multi_pod_ok", elapsed_us, f"ok={len(multi)}")]


if __name__ == "__main__":
    run()
