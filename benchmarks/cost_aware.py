"""Benchmark: Fig 7 — cost-aware provisioning with data-egress costs.

A month of hourly C4.8xlarge spot provisioning under four strategies
(paper §VII-E): cheapest / most-expensive in one AZ, cheapest within the
data's region, cheapest across all regions (+ $0.02/GB inter-region egress
per Eq (4)-(5)). Reproduces the paper's findings: multi-AZ/region search
saves money, but co-location wins as per-job data volume grows.
"""
from __future__ import annotations

import time

from repro.core import DEFAULT_ZONES, SpotMarket
from repro.core.cost import StoragePricing

INSTANCE = "c4.8xlarge"
HOURS = 720
DATA_GB = (0.0, 1.0, 5.0, 10.0, 25.0, 50.0, 100.0)
DATA_REGION = "us-east-1"


def month_cost(market: SpotMarket, strategy: str, data_gb: float) -> float:
    egress = StoragePricing().inter_region_transfer_per_gb
    home = [z for z in DEFAULT_ZONES if z.region == DATA_REGION]
    total = 0.0
    for h in range(HOURS):
        if strategy == "single_az_cheapest":
            zone, price = home[0], market.price(home[0], INSTANCE, h)
        elif strategy == "single_az_worst":
            prices = [(market.price(z, INSTANCE, h), z) for z in home]
            price, zone = max(prices, key=lambda t: t[0])
        elif strategy == "region_cheapest":
            zone, price = market.cheapest_zone(INSTANCE, h, tuple(home))
        elif strategy == "global_cheapest":
            zone, price = market.cheapest_zone(INSTANCE, h)
        else:
            raise ValueError(strategy)
        total += price
        if zone.region != DATA_REGION:
            total += 2 * data_gb * egress  # down + up, Eq (5)
    return total


def run(verbose: bool = True, seed: int = 11):
    market = SpotMarket(seed=seed)
    t0 = time.perf_counter()
    strategies = ["single_az_worst", "single_az_cheapest", "region_cheapest",
                  "global_cheapest"]
    table = {s: [month_cost(market, s, d) for d in DATA_GB]
             for s in strategies}
    elapsed_us = (time.perf_counter() - t0) * 1e6 / (len(strategies)
                                                     * len(DATA_GB))
    if verbose:
        print("\n== Fig 7: monthly cost, c4.8xlarge, by data volume/job ==")
        print(f"{'GB/job':>7}" + "".join(f"{s:>20}" for s in strategies))
        for i, d in enumerate(DATA_GB):
            print(f"{d:>7.0f}" + "".join(f"{table[s][i]:>20.2f}"
                                         for s in strategies))
    # paper's two findings
    az_risk = table["single_az_worst"][0] / table["single_az_cheapest"][0]
    crossover = next((d for i, d in enumerate(DATA_GB)
                      if table["global_cheapest"][i]
                      >= table["region_cheapest"][i]), None)
    if verbose:
        print(f"single-AZ price risk: worst/cheapest = {az_risk:.2f}x")
        print(f"co-location crossover: global search loses to in-region at "
              f"~{crossover} GB/job (paper: 'diminishing returns as data "
              f"grows')")
    return [("cost_aware.az_risk", elapsed_us, f"worst/best={az_risk:.2f}x"),
            ("cost_aware.crossover", elapsed_us,
             f"crossover_gb={crossover}")]


if __name__ == "__main__":
    run()
