"""Benchmark: decode serving — static vs continuous batching, dense vs paged
chunked prefill, and copy-on-write prefix sharing.

The serving analogue of the paper's elastic-vs-static provisioning tables
plus its shared-dataset tiering:

1. ``decode``: static engine (dense max_len cache per request, one host
   dispatch per token) vs the continuous engine (shared paged KV pool,
   admit/evict between on-device decode chunks) — decode tokens/s and
   p50/p95 per-token latency at batch 1/8/32 with mixed prompt lengths.
2. ``ttft_long``: admission (time-to-first-token) for long prompts of
   previously unseen lengths — the PR-1 dense path re-pays a pad-bucket jit
   compile per new length, the paged chunked path reuses one fixed-shape
   signature.
3. ``shared_prefix``: batch 8 requests sharing a hot system prompt — the
   paged engine aliases the cached prefix pages copy-on-write and prefills
   only each request's unique tail, so admission cost is O(new tokens).
4. ``spec_decode``: a repetitive/structured workload (small-vocab templated
   output, the prompt self-seeded with the model's own greedy prefix, more
   requests than slots) decoded with and without self-speculative decoding —
   the spec engine drafts ``spec_tokens`` candidates per step by n-gram
   lookup over the slot's own history and verifies them all in one FUSED
   draft+verify multi-query paged pass, emitting several tokens per engine
   step. Also runs the per-slot adaptive-window variant
   (``spec_adaptive_k``) on the same high-acceptance workload — it must not
   regress there. Reports decode-phase tokens/s, the mean accepted draft
   length and the mean per-slot accept-rate EMA. Reps INTERLEAVE the
   engines (base, spec, adaptive, base, ...), each taking its best rep, so
   a throttled host window penalizes all engines alike.
5. ``spec_low_accept``: the adversarial speculation workload — full-vocab
   random prompts whose continuations the n-gram drafter almost never
   predicts. Fixed-K speculation pays K verify rows per step for ~0
   accepted drafts; the adaptive controller collapses each slot's window
   to 1 (and the chunk dispatch to the smallest verify bucket), recovering
   most of the plain-decode rate.
6. ``quantized_kv``: the int8-quantized KV pool vs the f32 pool — decode
   tok/s at one batch point (greedy tokens asserted identical) plus the
   slot-token capacity each layout buys at a fixed pool byte budget
   (int8 rows + per-row f32 scales vs f32 rows: ~4*hd/(hd+4)x).

Rows feed the ``name,us_per_call,derived`` CSV that ``benchmarks/run.py``
prints, and the full results land in ``BENCH_serve.json`` (tokens/s, TTFT,
prefix hit rate, accepted draft length) so the perf trajectory is tracked
across PRs. ``--smoke`` runs a single-batch-point subset on the tiny config
for CI (perf-path breakage, not perf numbers).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine

ARCH = "yi-6b"
PROMPT_LENS = (5, 12, 24, 40)       # cycled per request (mixed, ragged)
MAX_NEW = 32
BATCHES = (1, 8, 32)
DECODE_CHUNK = None                 # None -> the engine occupancy heuristic

SPEC_BATCH = 8                      # spec-decode scenario: decode slots
SPEC_REQUESTS = 16                  # > slots: retired slots backfill
SPEC_VOCAB = 4                      # templated-output regime: tiny alphabet
                                    # keeps the random-init model's greedy
                                    # trajectory in short stable cycles, so
                                    # the accept rate is reproducible across
                                    # hosts/thread counts
SPEC_PATTERN = 6                    # repeating period of the prompt
SPEC_PROMPT_REPS = 4
SPEC_SEED = 48                      # model's own tokens prepended to context
SPEC_MAX_NEW = 128                  # long decode: acceptance dominates
SPEC_K = 8                          # draft window for the scenario (the high
                                    # accept rate supports a longer window
                                    # than the general-purpose default)

PREFIX_LEN = 96                     # shared system prompt (12 pages of 8)
TAIL_LEN = 8                        # per-request unique suffix
SHARED_BATCH = 8
PREFILL_CHUNK = 8                   # sized to the expected suffix work
LONG_LENS = (71, 83, 97, 109)       # each a fresh pad bucket for dense

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _build():
    cfg = get_reduced_config(ARCH).replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(batch: int, vocab: int):
    rng = np.random.RandomState(0)
    return [rng.randint(0, vocab, size=PROMPT_LENS[i % len(PROMPT_LENS)])
            .tolist() for i in range(batch)]


def _bench_decode_point(cfg, params, prompts, max_len, max_new, reps=1):
    """Static vs continuous at one batch point.

    Repetitions INTERLEAVE the two engines (static, continuous, static, ...)
    and each takes its best rep: on a throttled/loaded host a slow window
    then penalizes both engines alike instead of whichever happened to run
    second, which is what keeps the speedup *ratio* (the metric the CI
    regression gate checks) reproducible when absolute tok/s is not.
    """
    static = ServeEngine(cfg, params, max_len=max_len)
    # One continuous engine for warmup + measurement: the decode-chunk /
    # prefill jits are per-engine closures, so a fresh engine would re-pay
    # compilation. Prefix cache off: these rows track decode batching;
    # re-running the same prompts with the cache hot would measure admission
    # aliasing instead (the shared_prefix rows cover that).
    # decode_chunk=None exercises the occupancy heuristic; at low batch it
    # picks a chunk >= max_new, so the whole decode is one chunk and
    # p50 == p95 there (tail latency is only meaningful in the
    # high-occupancy rows, where chunks are short).
    cont = ContinuousBatchingEngine(
        cfg, params, max_len=max_len,
        max_slots=min(len(prompts), cfg.max_decode_slots * 4),
        decode_chunk=DECODE_CHUNK, enable_prefix_cache=False)

    def run_cont(chunk_times):
        t0 = time.perf_counter()
        out = cont.generate(prompts, max_new=max_new,
                            on_chunk=lambda steps, s: chunk_times.append(
                                (steps, s)))
        return out, time.perf_counter() - t0

    static.generate(prompts, max_new=4)               # warm the jit caches
    run_cont([])
    s_dt = c_dt = np.inf
    chunk_times: list[tuple[int, float]] = []
    for _ in range(reps):
        t0 = time.perf_counter()
        s_out = static.generate(prompts, max_new=max_new)
        s_dt = min(s_dt, time.perf_counter() - t0)
        times: list[tuple[int, float]] = []
        c_out, dt = run_cont(times)
        if dt < c_dt:
            c_dt, chunk_times = dt, times
    # One device sync per static generate: every token lands in the same
    # burst, so its per-token latency is degenerate (p50 == p95 == mean).
    s_tps = s_out.tokens.size / s_dt
    s_lat = s_dt / max_new * 1e3
    # Inter-token latency per request stream: a chunk of k steps gives every
    # active slot k tokens in `s` seconds -> k samples of s/k.
    lat = np.concatenate([
        np.full(steps, s / max(steps, 1)) for steps, s in chunk_times])
    return (s_tps, s_lat, c_out.tokens.size / c_dt,
            float(np.percentile(lat, 50)) * 1e3,
            float(np.percentile(lat, 95)) * 1e3)


def _bench_decode(cfg, params, verbose, results, batches=BATCHES,
                  max_new=MAX_NEW, reps=1):
    rows = []
    if verbose:
        print("\n== serve: static batch vs continuous batching "
              f"({ARCH} reduced, mixed prompts {PROMPT_LENS}, "
              f"max_new={max_new}) ==")
        print(f"{'batch':>6}{'static tok/s':>14}{'cont tok/s':>12}"
              f"{'speedup':>9}{'p50 ms/tok':>12}{'p95 ms/tok':>12}")
    max_len = max(PROMPT_LENS) + max_new + 8
    for b in batches:
        prompts = _prompts(b, cfg.vocab_size)
        s_tps, s_lat, c_tps, p50, p95 = _bench_decode_point(
            cfg, params, prompts, max_len, max_new, reps=reps)
        speed = c_tps / s_tps
        if verbose:
            print(f"{b:>6}{s_tps:>14.0f}{c_tps:>12.0f}{speed:>8.2f}x"
                  f"{p50:>12.2f}{p95:>12.2f}")
        rows.append((f"serve.static.b{b}", 1e6 / s_tps,
                     f"tok_s={s_tps:.0f};lat_ms={s_lat:.2f}"))
        rows.append((f"serve.continuous.b{b}", 1e6 / c_tps,
                     f"tok_s={c_tps:.0f};p50_ms={p50:.2f};p95_ms={p95:.2f};"
                     f"speedup={speed:.2f}x"))
        results["decode"].append({
            "batch": b, "static_tok_s": s_tps, "continuous_tok_s": c_tps,
            "speedup": speed, "p50_ms": p50, "p95_ms": p95})
    return rows


def _interleaved_best(engines, prompts, max_new, reps):
    """Best-of-``reps`` per engine, reps INTERLEAVED across engines.

    Same rationale as ``_bench_decode_point``: on a throttled/loaded host a
    slow window penalizes every engine alike instead of whichever happened
    to run second, which keeps the RATIOS (what the CI regression gate
    checks) reproducible when absolute tok/s is not. Returns, per engine,
    the best rep's output, decode-phase tok/s (``admit_seconds`` excluded),
    total tok/s, and a snapshot of the engine stats from that rep.
    """
    for eng in engines.values():
        eng.generate(prompts, max_new=4)              # warm the jit caches
    best = {name: np.inf for name in engines}
    outs, stats = {}, {}
    for _ in range(reps):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            out = eng.generate(prompts, max_new=max_new)
            dt = time.perf_counter() - t0
            if dt < best[name]:
                best[name], outs[name] = dt, out
                stats[name] = dict(eng.stats)
                stats[name]["mean_accepted_len"] = eng.mean_accepted_len
                stats[name]["mean_accept_ema"] = eng.mean_accept_ema
    tok_s = {name: outs[name].tokens.size
             / (best[name] - stats[name]["admit_seconds"])
             for name in engines}
    total_s = {name: outs[name].tokens.size / best[name] for name in engines}
    return outs, tok_s, total_s, stats


def _bench_spec_decode(cfg, params, verbose, results, requests=SPEC_REQUESTS,
                       slots=SPEC_BATCH, max_new=SPEC_MAX_NEW,
                       seed_len=SPEC_SEED, reps=3):
    """Repetitive/structured workload: speculative vs plain continuous
    decode. The regime prompt-lookup drafting targets is templated output
    over a small effective vocabulary (boilerplate JSON, logs, code), so
    the scenario uses a ``SPEC_VOCAB``-token variant of the model and each
    prompt carries a short repeating pattern plus the model's OWN first
    ``seed_len`` greedy tokens (generated once up front): the
    continuation's structure is already in context and the drafter proposes
    it verbatim. Greedy decode is deterministic, so self-seeding leaves the
    measured continuation identical between engines. More requests than
    slots keeps continuous batching backfilling: slots whose drafts verify
    fast retire early and take queued work instead of idling in lockstep.

    The per-slot adaptive-window engine (``spec_adaptive_k``) runs on the
    same high-acceptance workload: its windows should stay wide here and
    its tok/s should track the fixed-K engine (the low-acceptance scenario
    is where adaptation pays). Reported tokens/s is the DECODE phase
    (``admit_seconds`` excluded): admission cost is identical across the
    engines and is tracked by the ttft/shared-prefix rows; total-time
    throughput is recorded alongside.
    """
    from repro.models import get_family
    from repro.models.params import init_params
    scfg = cfg.replace(vocab_size=SPEC_VOCAB)
    sparams = init_params(get_family(scfg).layout(scfg), jax.random.PRNGKey(0),
                          scfg.param_dtype)
    rng = np.random.RandomState(7)
    pattern = rng.randint(0, SPEC_VOCAB, size=SPEC_PATTERN).tolist()
    heads = [pattern * SPEC_PROMPT_REPS
             + rng.randint(0, SPEC_VOCAB, size=1 + i % 3).tolist()
             for i in range(requests)]
    max_len = max(len(p) for p in heads) + seed_len + max_new + 8

    def engine(spec, adaptive=False):
        return ContinuousBatchingEngine(
            scfg, sparams, max_len=max_len, max_slots=slots,
            enable_prefix_cache=False, enable_spec_decode=spec,
            spec_tokens=SPEC_K, spec_adaptive_k=adaptive)

    base_eng = engine(False)
    seed = base_eng.generate(heads, max_new=seed_len).tokens  # also warms jit
    prompts = [h + seed[i].tolist() for i, h in enumerate(heads)]
    engines = {"base": base_eng, "spec": engine(True),
               "adaptive": engine(True, adaptive=True)}
    outs, tok_s, total_s, stats = _interleaved_best(
        engines, prompts, max_new, reps)
    for name in ("spec", "adaptive"):
        assert np.array_equal(outs["base"].tokens, outs[name].tokens), \
            f"{name} speculative decode diverged from the greedy path"
    speed = tok_s["spec"] / tok_s["base"]
    adaptive_vs_spec = tok_s["adaptive"] / tok_s["spec"]
    acc = stats["spec"]["mean_accepted_len"]
    steps_per_tok = (stats["spec"]["spec_steps"]
                     / max(stats["spec"]["spec_emitted"], 1))
    if verbose:
        print(f"\n== serve: speculative decode, repetitive workload "
              f"({requests} reqs / {slots} slots, vocab {SPEC_VOCAB}, "
              f"pattern {SPEC_PATTERN}x{SPEC_PROMPT_REPS} + {seed_len} "
              f"self-seeded, max_new={max_new}, K={SPEC_K}) ==")
        print(f"plain {tok_s['base']:.0f} decode tok/s   spec "
              f"{tok_s['spec']:.0f} decode tok/s   speedup {speed:.2f}x   "
              f"mean accepted {acc:.2f}/{SPEC_K}   steps/token "
              f"{steps_per_tok:.2f}")
        print(f"adaptive-K {tok_s['adaptive']:.0f} decode tok/s   "
              f"vs fixed-K {adaptive_vs_spec:.2f}x   accept EMA "
              f"{stats['adaptive']['mean_accept_ema']:.2f}")
    results["spec_decode"] = {
        "requests": requests, "slots": slots, "vocab": SPEC_VOCAB,
        "max_new": max_new, "seed_len": seed_len,
        "spec_tokens": SPEC_K, "reps": reps,
        "base_decode_tok_s": tok_s["base"],
        "spec_decode_tok_s": tok_s["spec"],
        "decode_speedup": speed,
        "base_total_tok_s": total_s["base"],
        "spec_total_tok_s": total_s["spec"],
        "total_speedup": total_s["spec"] / total_s["base"],
        "mean_accepted_len": acc, "steps_per_token": steps_per_tok,
        "mean_accept_ema": stats["spec"]["mean_accept_ema"],
        "adaptive_decode_tok_s": tok_s["adaptive"],
        "adaptive_vs_spec": adaptive_vs_spec,
        "adaptive_mean_accepted_len": stats["adaptive"]["mean_accepted_len"],
        "adaptive_mean_accept_ema": stats["adaptive"]["mean_accept_ema"]}
    return [(f"serve.spec.base.b{slots}", 1e6 / tok_s["base"],
             f"tok_s={tok_s['base']:.0f}"),
            (f"serve.spec.on.b{slots}", 1e6 / tok_s["spec"],
             f"tok_s={tok_s['spec']:.0f};speedup={speed:.2f}x;"
             f"accepted={acc:.2f}"),
            (f"serve.spec.adaptive.b{slots}", 1e6 / tok_s["adaptive"],
             f"tok_s={tok_s['adaptive']:.0f};"
             f"vs_spec={adaptive_vs_spec:.2f}x")]


def _bench_spec_low_accept(cfg, params, verbose, results,
                           requests=SPEC_REQUESTS, slots=SPEC_BATCH,
                           max_new=64, reps=3):
    """Adversarial speculation: full-vocab random prompts the n-gram drafter
    cannot predict (acceptance ~ 1/vocab). Fixed-K speculation pays K extra
    verify rows per step for nothing; the adaptive controller shrinks each
    slot's window toward 1 and the chunk dispatch drops to the smallest
    verify bucket, recovering most of the plain-decode rate. The gate
    metric is adaptive tok/s >= fixed-K tok/s on this workload.

    ``decode_chunk`` is pinned short: the controller observes acceptance
    only at chunk boundaries, so the occupancy heuristic's
    one-chunk-per-request choice at low batch would freeze every window at
    K for the whole request. Short chunks are also the production regime
    (deadline-aware preemption already bounds chunk length).
    """
    prompts = _prompts(requests, cfg.vocab_size)
    max_len = max(PROMPT_LENS) + max_new + 8

    def engine(spec, adaptive=False):
        return ContinuousBatchingEngine(
            cfg, params, max_len=max_len, max_slots=slots, decode_chunk=8,
            enable_prefix_cache=False, enable_spec_decode=spec,
            spec_tokens=SPEC_K, spec_adaptive_k=adaptive)

    engines = {"base": engine(False), "spec": engine(True),
               "adaptive": engine(True, adaptive=True)}
    outs, tok_s, _, stats = _interleaved_best(engines, prompts, max_new, reps)
    for name in ("spec", "adaptive"):
        assert np.array_equal(outs["base"].tokens, outs[name].tokens), \
            f"{name} speculative decode diverged from the greedy path"
    adaptive_vs_spec = tok_s["adaptive"] / tok_s["spec"]
    buckets = sorted(engines["adaptive"]._spec_chunks)
    if verbose:
        print(f"\n== serve: speculative decode, LOW-acceptance workload "
              f"({requests} reqs / {slots} slots, full vocab "
              f"{cfg.vocab_size}, max_new={max_new}, K={SPEC_K}) ==")
        print(f"plain {tok_s['base']:.0f}   fixed-K {tok_s['spec']:.0f}   "
              f"adaptive-K {tok_s['adaptive']:.0f} decode tok/s   "
              f"adaptive/fixed {adaptive_vs_spec:.2f}x   "
              f"accepted {stats['spec']['mean_accepted_len']:.2f} -> "
              f"verify buckets used {buckets}")
    results["spec_low_accept"] = {
        "requests": requests, "slots": slots, "max_new": max_new,
        "spec_tokens": SPEC_K, "reps": reps,
        "base_decode_tok_s": tok_s["base"],
        "spec_decode_tok_s": tok_s["spec"],
        "adaptive_decode_tok_s": tok_s["adaptive"],
        "adaptive_vs_spec": adaptive_vs_spec,
        "spec_mean_accepted_len": stats["spec"]["mean_accepted_len"],
        "adaptive_mean_accept_ema": stats["adaptive"]["mean_accept_ema"],
        "adaptive_buckets_used": buckets}
    return [(f"serve.spec_low.fixed.b{slots}", 1e6 / tok_s["spec"],
             f"tok_s={tok_s['spec']:.0f}"),
            (f"serve.spec_low.adaptive.b{slots}", 1e6 / tok_s["adaptive"],
             f"tok_s={tok_s['adaptive']:.0f};"
             f"vs_fixed={adaptive_vs_spec:.2f}x")]


def _bench_quantized_kv(cfg, params, verbose, results, batch=SPEC_BATCH,
                        max_new=MAX_NEW, reps=3):
    """int8-quantized KV pool vs the f32 pool.

    Two numbers: decode tok/s at one batch point (greedy tokens asserted
    IDENTICAL — per-row symmetric quantization perturbs logits but not the
    argmax on this workload), and bytes per pooled slot-token for each
    layout. ``capacity_ratio`` is how many more slot-tokens the int8 layout
    (int8 rows + one f32 scale per row, per K and V) packs into the same
    pool byte budget: 4*hd/(hd+4), ~3.9x at production head dims. It is
    computed from the engines' actual pool buffers, so any layout
    regression (dropped scale page, widened dtype) moves it.
    """
    prompts = _prompts(batch, cfg.vocab_size)
    max_len = max(PROMPT_LENS) + max_new + 8
    engines = {dt: ContinuousBatchingEngine(
                   cfg, params, max_len=max_len, max_slots=batch,
                   enable_prefix_cache=False, kv_cache_dtype=dt)
               for dt in ("f32", "int8")}
    bytes_per_tok = {
        dt: sum(leaf.nbytes for leaf in eng.pool.values())
        / (eng.num_pages * cfg.page_size)
        for dt, eng in engines.items()}
    capacity_ratio = bytes_per_tok["f32"] / bytes_per_tok["int8"]
    outs, tok_s, _, _ = _interleaved_best(engines, prompts, max_new, reps)
    assert np.array_equal(outs["f32"].tokens, outs["int8"].tokens), \
        "int8 KV decode diverged from the f32 greedy path"
    tok_s_ratio = tok_s["int8"] / tok_s["f32"]
    if verbose:
        print(f"\n== serve: int8-quantized KV pool (batch {batch}, "
              f"max_new={max_new}) ==")
        print(f"f32 {tok_s['f32']:.0f} decode tok/s   int8 "
              f"{tok_s['int8']:.0f} decode tok/s   ratio "
              f"{tok_s_ratio:.2f}x   bytes/slot-token "
              f"{bytes_per_tok['f32']:.0f} -> {bytes_per_tok['int8']:.0f}   "
              f"capacity {capacity_ratio:.2f}x")
    results["quantized_kv"] = {
        "batch": batch, "max_new": max_new, "reps": reps,
        "f32_decode_tok_s": tok_s["f32"],
        "int8_decode_tok_s": tok_s["int8"],
        "decode_tok_s_ratio": tok_s_ratio,
        "f32_bytes_per_slot_token": bytes_per_tok["f32"],
        "int8_bytes_per_slot_token": bytes_per_tok["int8"],
        "capacity_ratio": capacity_ratio,
        "token_identical": True}
    return [(f"serve.kv_int8.b{batch}", 1e6 / tok_s["int8"],
             f"tok_s={tok_s['int8']:.0f};vs_f32={tok_s_ratio:.2f}x;"
             f"capacity={capacity_ratio:.2f}x")]


def _admit_engines(cfg, params, max_len, max_slots):
    dense = ContinuousBatchingEngine(
        cfg, params, max_len=max_len, max_slots=max_slots, decode_chunk=2,
        prefill_mode="dense", enable_prefix_cache=False)
    paged = ContinuousBatchingEngine(
        cfg, params, max_len=max_len, max_slots=max_slots, decode_chunk=2,
        prefill_chunk=PREFILL_CHUNK)
    return dense, paged


def _bench_ttft_long(cfg, params, verbose, results):
    """Admission for long prompts of fresh lengths: dense re-pays a pad-bucket
    compile per length; chunked prefill keeps one fixed signature."""
    rng = np.random.RandomState(1)
    max_len = max(LONG_LENS) + 16
    dense, paged = _admit_engines(cfg, params, max_len, max_slots=1)
    warm = [rng.randint(0, cfg.vocab_size, size=33).tolist()]
    dense.generate(warm, max_new=1)
    paged.generate(warm, max_new=1)

    ttft = {}
    for name, eng in (("dense", dense), ("paged", paged)):
        total = 0.0
        for n in LONG_LENS:                     # every length is first-seen
            eng.generate([rng.randint(0, cfg.vocab_size, size=n).tolist()],
                         max_new=1)
            total += eng.stats["admit_seconds"]
        ttft[name] = total / len(LONG_LENS) * 1e3          # ms
    speed = ttft["dense"] / ttft["paged"]
    if verbose:
        print(f"\n== serve: long-prompt TTFT, fresh lengths {LONG_LENS} ==")
        print(f"dense prefill {ttft['dense']:.1f} ms   paged chunked "
              f"{ttft['paged']:.1f} ms   speedup {speed:.2f}x")
    results["ttft_long"] = {"lens": list(LONG_LENS),
                            "dense_ttft_ms": ttft["dense"],
                            "paged_ttft_ms": ttft["paged"], "speedup": speed}
    return [("serve.ttft_long.dense", ttft["dense"] * 1e3,
             f"ttft_ms={ttft['dense']:.2f}"),
            ("serve.ttft_long.paged", ttft["paged"] * 1e3,
             f"ttft_ms={ttft['paged']:.2f};speedup={speed:.2f}x")]


def _bench_shared_prefix(cfg, params, verbose, results, batch=SHARED_BATCH,
                         prefix_len=PREFIX_LEN, rounds=5):
    """Shared-system-prompt admission: paged aliases the cached prefix pages
    and prefills only each request's unique tail."""
    rng = np.random.RandomState(2)
    prefix = rng.randint(0, cfg.vocab_size, size=prefix_len).tolist()

    def mk():
        return [prefix + rng.randint(0, cfg.vocab_size, size=TAIL_LEN).tolist()
                for _ in range(batch)]

    max_len = prefix_len + TAIL_LEN + 16
    dense, paged = _admit_engines(cfg, params, max_len, max_slots=batch)
    # Warmup: two rounds compile both paths — cold prefill AND the
    # cache-hit/aliasing path — and leave the prefix pages hot in the paged
    # engine's cache, the steady state of a shared system prompt.
    for _ in range(2):
        dense.generate(mk(), max_new=1)
        paged.generate(mk(), max_new=1)

    # Best of N rounds: admission is a few-ms host+dispatch sequence, so a
    # loaded machine contaminates individual rounds far more than the steady
    # state; the min is the reproducible number.
    d_ms, p_ms, hit = np.inf, np.inf, 0.0
    for _ in range(rounds):
        dense.generate(mk(), max_new=1)
        d_ms = min(d_ms, dense.stats["admit_seconds"] * 1e3)
        paged.generate(mk(), max_new=1)
        p_ms = min(p_ms, paged.stats["admit_seconds"] * 1e3)
        hit = max(hit, paged.prefix_hit_rate)
    speed = d_ms / p_ms
    if verbose:
        print(f"\n== serve: shared-prefix admission (batch {batch}, "
              f"{prefix_len}-token system prompt + {TAIL_LEN}-token tails) ==")
        print(f"dense prefill {d_ms:.1f} ms   paged+prefix {p_ms:.1f} ms   "
              f"speedup {speed:.2f}x   prefix hit rate {hit:.2f}")
    results["shared_prefix"] = {
        "batch": batch, "prefix_len": prefix_len, "tail_len": TAIL_LEN,
        "dense_admit_ms": d_ms, "paged_admit_ms": p_ms,
        "admission_speedup": speed, "prefix_hit_rate": hit}
    return [(f"serve.prefix.dense.b{batch}", d_ms * 1e3,
             f"admit_ms={d_ms:.2f}"),
            (f"serve.prefix.paged.b{batch}", p_ms * 1e3,
             f"admit_ms={p_ms:.2f};speedup={speed:.2f}x;hit_rate={hit:.2f}")]


def run(verbose: bool = True, json_path: str | Path | None = JSON_PATH,
        smoke: bool = False):
    cfg, params = _build()
    results: dict = {"arch": ARCH, "max_new": MAX_NEW, "decode": [],
                     "failures": []}
    if smoke:
        # CI gate: one batch point through every serve hot path (static,
        # continuous, prefix-sharing, speculative) on the tiny config —
        # catches perf-path breakage, not perf numbers.
        results["smoke"] = True
        results["max_new"] = 8          # what the smoke decode rows measure
        scenarios = [
            ("decode", lambda: _bench_decode(cfg, params, verbose, results,
                                             batches=(4,), max_new=8,
                                             reps=5)),
            ("shared_prefix", lambda: _bench_shared_prefix(
                cfg, params, verbose, results, batch=4, prefix_len=32,
                rounds=2)),
            ("spec_decode", lambda: _bench_spec_decode(
                cfg, params, verbose, results, requests=4, slots=4,
                max_new=16, seed_len=24, reps=5)),
            ("spec_low_accept", lambda: _bench_spec_low_accept(
                cfg, params, verbose, results, requests=4, slots=4,
                max_new=16, reps=3)),
            ("quantized_kv", lambda: _bench_quantized_kv(
                cfg, params, verbose, results, batch=4, max_new=8, reps=5)),
        ]
    else:
        scenarios = [
            ("decode", lambda: _bench_decode(cfg, params, verbose, results)),
            ("ttft_long", lambda: _bench_ttft_long(cfg, params, verbose,
                                                   results)),
            ("shared_prefix", lambda: _bench_shared_prefix(
                cfg, params, verbose, results)),
            ("spec_decode", lambda: _bench_spec_decode(cfg, params, verbose,
                                                       results)),
            ("spec_low_accept", lambda: _bench_spec_low_accept(
                cfg, params, verbose, results)),
            ("quantized_kv", lambda: _bench_quantized_kv(cfg, params, verbose,
                                                         results)),
        ]
    rows = []
    for name, fn in scenarios:
        # Attempt every scenario, then fail the bench as a whole if any
        # raised — after writing the JSON. A half-run bench must exit
        # nonzero so the CI regression gate cannot read it as healthy.
        try:
            rows.extend(fn())
        except Exception as e:                      # noqa: BLE001
            results["failures"].append(f"{name}: {type(e).__name__}: {e}")
            if verbose:
                print(f"\n!! scenario {name} FAILED: {e}")
    if json_path is not None:
        Path(json_path).write_text(json.dumps(results, indent=2) + "\n")
        if verbose:
            print(f"\nwrote {json_path}")
    if results["failures"]:
        raise RuntimeError(
            f"{len(results['failures'])} serve bench scenario(s) failed: "
            + "; ".join(results["failures"]))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 batch point, tiny shapes (CI perf-path gate)")
    ap.add_argument("--json", default=None,
                    help="results path (default: BENCH_serve.json, or "
                         "BENCH_serve.smoke.json with --smoke)")
    args = ap.parse_args()
    path = args.json or (JSON_PATH.with_suffix(".smoke.json") if args.smoke
                         else JSON_PATH)
    run(smoke=args.smoke, json_path=path)
