"""Benchmark: decode serving — static vs continuous batching, dense vs paged
chunked prefill, and copy-on-write prefix sharing.

The serving analogue of the paper's elastic-vs-static provisioning tables
plus its shared-dataset tiering:

1. ``decode``: static engine (dense max_len cache per request, one host
   dispatch per token) vs the continuous engine (shared paged KV pool,
   admit/evict between on-device decode chunks) — decode tokens/s and
   p50/p95 per-token latency at batch 1/8/32 with mixed prompt lengths.
2. ``ttft_long``: admission (time-to-first-token) for long prompts of
   previously unseen lengths — the PR-1 dense path re-pays a pad-bucket jit
   compile per new length, the paged chunked path reuses one fixed-shape
   signature.
3. ``shared_prefix``: batch 8 requests sharing a hot system prompt — the
   paged engine aliases the cached prefix pages copy-on-write and prefills
   only each request's unique tail, so admission cost is O(new tokens).

Rows feed the ``name,us_per_call,derived`` CSV that ``benchmarks/run.py``
prints, and the full results land in ``BENCH_serve.json`` (tokens/s, TTFT,
prefix hit rate) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine

ARCH = "yi-6b"
PROMPT_LENS = (5, 12, 24, 40)       # cycled per request (mixed, ragged)
MAX_NEW = 32
BATCHES = (1, 8, 32)
DECODE_CHUNK = 16

PREFIX_LEN = 96                     # shared system prompt (12 pages of 8)
TAIL_LEN = 8                        # per-request unique suffix
SHARED_BATCH = 8
PREFILL_CHUNK = 8                   # sized to the expected suffix work
LONG_LENS = (71, 83, 97, 109)       # each a fresh pad bucket for dense

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"


def _build():
    cfg = get_reduced_config(ARCH).replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(batch: int, vocab: int):
    rng = np.random.RandomState(0)
    return [rng.randint(0, vocab, size=PROMPT_LENS[i % len(PROMPT_LENS)])
            .tolist() for i in range(batch)]


def _bench_static(cfg, params, prompts, max_len):
    eng = ServeEngine(cfg, params, max_len=max_len)
    eng.generate(prompts, max_new=4)                  # warm the jit caches
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=MAX_NEW)
    dt = time.perf_counter() - t0
    n_tok = out.tokens.size
    # One device sync per generate: every token lands in the same burst, so
    # the per-token latency distribution is degenerate (p50 == p95 == mean).
    return n_tok / dt, dt / MAX_NEW * 1e3


def _bench_continuous(cfg, params, prompts, max_len):
    # One engine for warmup + measurement: the decode-chunk/prefill jits are
    # per-engine closures, so a fresh engine would re-pay compilation.
    # Prefix cache off: these rows track decode batching; re-running the same
    # prompts with the cache hot would measure admission aliasing instead
    # (the shared_prefix rows cover that).
    eng = ContinuousBatchingEngine(
        cfg, params, max_len=max_len,
        max_slots=min(len(prompts), cfg.max_decode_slots * 4),
        decode_chunk=DECODE_CHUNK, enable_prefix_cache=False)

    def run(chunk_times):
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new=MAX_NEW,
                           on_chunk=lambda steps, s: chunk_times.append(
                               (steps, s)))
        return out, time.perf_counter() - t0

    run([])                                           # warm the jit caches
    chunk_times: list[tuple[int, float]] = []
    out, dt = run(chunk_times)
    n_tok = out.tokens.size
    # Inter-token latency per request stream: a chunk of k steps gives every
    # active slot k tokens in `s` seconds -> k samples of s/k.
    lat = np.concatenate([
        np.full(steps, s / max(steps, 1)) for steps, s in chunk_times])
    return (n_tok / dt,
            float(np.percentile(lat, 50)) * 1e3,
            float(np.percentile(lat, 95)) * 1e3)


def _bench_decode(cfg, params, verbose, results):
    rows = []
    if verbose:
        print("\n== serve: static batch vs continuous batching "
              f"({ARCH} reduced, mixed prompts {PROMPT_LENS}, "
              f"max_new={MAX_NEW}) ==")
        print(f"{'batch':>6}{'static tok/s':>14}{'cont tok/s':>12}"
              f"{'speedup':>9}{'p50 ms/tok':>12}{'p95 ms/tok':>12}")
    max_len = max(PROMPT_LENS) + MAX_NEW + 8
    for b in BATCHES:
        prompts = _prompts(b, cfg.vocab_size)
        s_tps, s_lat = _bench_static(cfg, params, prompts, max_len)
        c_tps, p50, p95 = _bench_continuous(cfg, params, prompts, max_len)
        speed = c_tps / s_tps
        if verbose:
            print(f"{b:>6}{s_tps:>14.0f}{c_tps:>12.0f}{speed:>8.2f}x"
                  f"{p50:>12.2f}{p95:>12.2f}")
        rows.append((f"serve.static.b{b}", 1e6 / s_tps,
                     f"tok_s={s_tps:.0f};lat_ms={s_lat:.2f}"))
        rows.append((f"serve.continuous.b{b}", 1e6 / c_tps,
                     f"tok_s={c_tps:.0f};p50_ms={p50:.2f};p95_ms={p95:.2f};"
                     f"speedup={speed:.2f}x"))
        results["decode"].append({
            "batch": b, "static_tok_s": s_tps, "continuous_tok_s": c_tps,
            "speedup": speed, "p50_ms": p50, "p95_ms": p95})
    return rows


def _admit_engines(cfg, params, max_len, max_slots):
    dense = ContinuousBatchingEngine(
        cfg, params, max_len=max_len, max_slots=max_slots, decode_chunk=2,
        prefill_mode="dense", enable_prefix_cache=False)
    paged = ContinuousBatchingEngine(
        cfg, params, max_len=max_len, max_slots=max_slots, decode_chunk=2,
        prefill_chunk=PREFILL_CHUNK)
    return dense, paged


def _bench_ttft_long(cfg, params, verbose, results):
    """Admission for long prompts of fresh lengths: dense re-pays a pad-bucket
    compile per length; chunked prefill keeps one fixed signature."""
    rng = np.random.RandomState(1)
    max_len = max(LONG_LENS) + 16
    dense, paged = _admit_engines(cfg, params, max_len, max_slots=1)
    warm = [rng.randint(0, cfg.vocab_size, size=33).tolist()]
    dense.generate(warm, max_new=1)
    paged.generate(warm, max_new=1)

    ttft = {}
    for name, eng in (("dense", dense), ("paged", paged)):
        total = 0.0
        for n in LONG_LENS:                     # every length is first-seen
            eng.generate([rng.randint(0, cfg.vocab_size, size=n).tolist()],
                         max_new=1)
            total += eng.stats["admit_seconds"]
        ttft[name] = total / len(LONG_LENS) * 1e3          # ms
    speed = ttft["dense"] / ttft["paged"]
    if verbose:
        print(f"\n== serve: long-prompt TTFT, fresh lengths {LONG_LENS} ==")
        print(f"dense prefill {ttft['dense']:.1f} ms   paged chunked "
              f"{ttft['paged']:.1f} ms   speedup {speed:.2f}x")
    results["ttft_long"] = {"lens": list(LONG_LENS),
                            "dense_ttft_ms": ttft["dense"],
                            "paged_ttft_ms": ttft["paged"], "speedup": speed}
    return [("serve.ttft_long.dense", ttft["dense"] * 1e3,
             f"ttft_ms={ttft['dense']:.2f}"),
            ("serve.ttft_long.paged", ttft["paged"] * 1e3,
             f"ttft_ms={ttft['paged']:.2f};speedup={speed:.2f}x")]


def _bench_shared_prefix(cfg, params, verbose, results):
    """Batch-8 admission with a hot shared system prompt: paged aliases the
    cached prefix pages and prefills only each request's unique tail."""
    rng = np.random.RandomState(2)
    prefix = rng.randint(0, cfg.vocab_size, size=PREFIX_LEN).tolist()

    def mk():
        return [prefix + rng.randint(0, cfg.vocab_size, size=TAIL_LEN).tolist()
                for _ in range(SHARED_BATCH)]

    max_len = PREFIX_LEN + TAIL_LEN + 16
    dense, paged = _admit_engines(cfg, params, max_len,
                                  max_slots=SHARED_BATCH)
    # Warmup: two rounds compile both paths — cold prefill AND the
    # cache-hit/aliasing path — and leave the prefix pages hot in the paged
    # engine's cache, the steady state of a shared system prompt.
    for _ in range(2):
        dense.generate(mk(), max_new=1)
        paged.generate(mk(), max_new=1)

    # Best of N rounds: admission is a few-ms host+dispatch sequence, so a
    # loaded machine contaminates individual rounds far more than the steady
    # state; the min is the reproducible number.
    d_ms, p_ms, hit = np.inf, np.inf, 0.0
    for _ in range(5):
        dense.generate(mk(), max_new=1)
        d_ms = min(d_ms, dense.stats["admit_seconds"] * 1e3)
        paged.generate(mk(), max_new=1)
        p_ms = min(p_ms, paged.stats["admit_seconds"] * 1e3)
        hit = max(hit, paged.prefix_hit_rate)
    speed = d_ms / p_ms
    if verbose:
        print(f"\n== serve: shared-prefix admission (batch {SHARED_BATCH}, "
              f"{PREFIX_LEN}-token system prompt + {TAIL_LEN}-token tails) ==")
        print(f"dense prefill {d_ms:.1f} ms   paged+prefix {p_ms:.1f} ms   "
              f"speedup {speed:.2f}x   prefix hit rate {hit:.2f}")
    results["shared_prefix"] = {
        "batch": SHARED_BATCH, "prefix_len": PREFIX_LEN, "tail_len": TAIL_LEN,
        "dense_admit_ms": d_ms, "paged_admit_ms": p_ms,
        "admission_speedup": speed, "prefix_hit_rate": hit}
    return [("serve.prefix.dense.b8", d_ms * 1e3, f"admit_ms={d_ms:.2f}"),
            ("serve.prefix.paged.b8", p_ms * 1e3,
             f"admit_ms={p_ms:.2f};speedup={speed:.2f}x;hit_rate={hit:.2f}")]


def run(verbose: bool = True, json_path: str | Path | None = JSON_PATH):
    cfg, params = _build()
    results: dict = {"arch": ARCH, "max_new": MAX_NEW, "decode": []}
    rows = _bench_decode(cfg, params, verbose, results)
    rows += _bench_ttft_long(cfg, params, verbose, results)
    rows += _bench_shared_prefix(cfg, params, verbose, results)
    if json_path is not None:
        Path(json_path).write_text(json.dumps(results, indent=2) + "\n")
        if verbose:
            print(f"\nwrote {json_path}")
    return rows


if __name__ == "__main__":
    run()
