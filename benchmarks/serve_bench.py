"""Benchmark: decode serving — static batch vs continuous batching.

The serving analogue of the paper's elastic-vs-static provisioning tables:
the static engine provisions one dense max_len cache per request and decodes
the padded batch with one host dispatch per token; the continuous engine
shares a paged KV pool, admits/evicts between on-device decode chunks, and
syncs with the host once per chunk.

Reports decode tokens/s and p50/p95 per-token latency at batch 1/8/32 with
mixed prompt lengths (CPU, jit). Rows feed the ``name,us_per_call,derived``
CSV that ``benchmarks/run.py`` prints.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine

ARCH = "yi-6b"
PROMPT_LENS = (5, 12, 24, 40)       # cycled per request (mixed, ragged)
MAX_NEW = 32
BATCHES = (1, 8, 32)
DECODE_CHUNK = 16


def _build():
    cfg = get_reduced_config(ARCH).replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(batch: int, vocab: int):
    rng = np.random.RandomState(0)
    return [rng.randint(0, vocab, size=PROMPT_LENS[i % len(PROMPT_LENS)])
            .tolist() for i in range(batch)]


def _bench_static(cfg, params, prompts, max_len):
    eng = ServeEngine(cfg, params, max_len=max_len)
    eng.generate(prompts, max_new=4)                  # warm the jit caches
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=MAX_NEW)
    dt = time.perf_counter() - t0
    n_tok = out.tokens.size
    # One device sync per generate: every token lands in the same burst, so
    # the per-token latency distribution is degenerate (p50 == p95 == mean).
    return n_tok / dt, dt / MAX_NEW * 1e3


def _bench_continuous(cfg, params, prompts, max_len):
    # One engine for warmup + measurement: the decode-chunk/prefill jits are
    # per-engine closures, so a fresh engine would re-pay compilation.
    eng = ContinuousBatchingEngine(
        cfg, params, max_len=max_len,
        max_slots=min(len(prompts), cfg.max_decode_slots * 4),
        decode_chunk=DECODE_CHUNK)

    def run(chunk_times):
        t0 = time.perf_counter()
        out = eng.generate(prompts, max_new=MAX_NEW,
                           on_chunk=lambda steps, s: chunk_times.append(
                               (steps, s)))
        return out, time.perf_counter() - t0

    run([])                                           # warm the jit caches
    chunk_times: list[tuple[int, float]] = []
    out, dt = run(chunk_times)
    n_tok = out.tokens.size
    # Inter-token latency per request stream: a chunk of k steps gives every
    # active slot k tokens in `s` seconds -> k samples of s/k.
    lat = np.concatenate([
        np.full(steps, s / max(steps, 1)) for steps, s in chunk_times])
    return (n_tok / dt,
            float(np.percentile(lat, 50)) * 1e3,
            float(np.percentile(lat, 95)) * 1e3)


def run(verbose: bool = True):
    cfg, params = _build()
    rows = []
    if verbose:
        print("\n== serve: static batch vs continuous batching "
              f"({ARCH} reduced, mixed prompts {PROMPT_LENS}, "
              f"max_new={MAX_NEW}) ==")
        print(f"{'batch':>6}{'static tok/s':>14}{'cont tok/s':>12}"
              f"{'speedup':>9}{'p50 ms/tok':>12}{'p95 ms/tok':>12}")
    max_len = max(PROMPT_LENS) + MAX_NEW + 8
    for b in BATCHES:
        prompts = _prompts(b, cfg.vocab_size)
        s_tps, s_lat = _bench_static(cfg, params, prompts, max_len)
        c_tps, p50, p95 = _bench_continuous(cfg, params, prompts, max_len)
        speed = c_tps / s_tps
        if verbose:
            print(f"{b:>6}{s_tps:>14.0f}{c_tps:>12.0f}{speed:>8.2f}x"
                  f"{p50:>12.2f}{p95:>12.2f}")
        rows.append((f"serve.static.b{b}", 1e6 / s_tps,
                     f"tok_s={s_tps:.0f};lat_ms={s_lat:.2f}"))
        rows.append((f"serve.continuous.b{b}", 1e6 / c_tps,
                     f"tok_s={c_tps:.0f};p50_ms={p50:.2f};p95_ms={p95:.2f};"
                     f"speedup={speed:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
