"""Benchmark harness: one module per paper table/figure + roofline.

Prints a ``name,us_per_call,derived`` CSV summary after the human-readable
tables. Usage: ``PYTHONPATH=src python -m benchmarks.run [--only NAME]``.
The serve benchmark additionally writes ``BENCH_serve.json`` (tokens/s,
TTFT, prefix hit rate) and the gateway benchmark ``BENCH_gateway.json``
(elastic vs static cost, deadline-hit rate, tenant isolation) so the perf
trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

from benchmarks import (cost_aware, elastic_scaling, gateway_bench, roofline,
                        serve_bench, storage_cost, throughput,
                        train_microbench)

BENCHES = {
    "storage_cost": storage_cost.run,        # paper Table III
    "elastic_scaling": elastic_scaling.run,  # paper Table VII-C + Fig 5
    "throughput": throughput.run,            # paper Fig 6
    "cost_aware": cost_aware.run,            # paper Fig 7
    "roofline": roofline.run,                # assignment §Roofline
    "train_microbench": train_microbench.run,
    "serve": serve_bench.run,                # continuous batching vs static
    "gateway": gateway_bench.run,            # elastic multi-tenant serving
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(BENCHES), default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    rows = []
    failures = []
    for name in names:
        # Run every requested bench even when one fails, then exit nonzero:
        # a raising scenario must never look like a clean (half-)run to CI.
        try:
            rows.extend(BENCHES[name](verbose=True))
        except Exception as e:                      # noqa: BLE001
            failures.append(f"{name}: {type(e).__name__}: {e}")
            print(f"\n!! bench {name} FAILED: {e}")
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print(f"\n{len(failures)} bench(es) failed:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)


if __name__ == "__main__":
    main()
