"""Benchmark: Table III — storage-cost projection for 10 TB over a year."""
from __future__ import annotations

import time

from repro.core import lifecycle_annual_cost

PAPER = {  # (policy, active_frac) -> (storage $, access $)
    ("STD", 0.0): (3546.0, 0.0),
    ("IA", 0.0): (1500.0, 0.0),
    ("GLACIER", 0.03): (840.0, 4217.2),
    ("STD30-IA", 0.0): (1670.5, 0.0),
    ("STD30-IA60-GLACIER", 0.03): (880.259, 169.73),
    ("STD30-IA60-GLACIER", 0.10): (974.20, 169.73),
}


def run(verbose: bool = True):
    rows = []
    t0 = time.perf_counter()
    for (policy, active), (p_storage, p_access) in PAPER.items():
        c = lifecycle_annual_cost(policy, 10_000.0, active)
        rows.append({
            "strategy": f"{policy}({active:.0%})" if active else policy,
            "storage_ours": round(c.storage_annual, 3),
            "storage_paper": p_storage,
            "access_ours": round(c.access_annual, 2),
            "access_paper": p_access,
            "access_hours": c.access_hours / 3600.0,
        })
    elapsed_us = (time.perf_counter() - t0) * 1e6 / len(rows)
    if verbose:
        print("\n== Table III: storage cost projection, 10TB/year ==")
        print(f"{'strategy':<26}{'$storage':>10}{'paper':>10}"
              f"{'$access':>10}{'paper':>10}")
        for r in rows:
            print(f"{r['strategy']:<26}{r['storage_ours']:>10.2f}"
                  f"{r['storage_paper']:>10.2f}{r['access_ours']:>10.2f}"
                  f"{r['access_paper']:>10.2f}")
        print("note: storage column reproduces the paper to the cent; the "
              "access column's burst profile is calibrated (see DESIGN.md).")
    best = min(rows, key=lambda r: r["storage_ours"] + r["access_ours"])
    return [("storage_cost.table3", elapsed_us,
             f"best={best['strategy']}:"
             f"${best['storage_ours'] + best['access_ours']:.0f}/yr")]


if __name__ == "__main__":
    run()
