"""Benchmark: the Kotta serving gateway — elastic spot replicas vs a static
on-demand fleet on a bursty multi-tenant trace.

The serving analogue of the paper's Table VII-C (elastic vs static
provisioning: makespan / cost / wait) plus its §VI isolation guarantees:

1. ``trace``: three tenants submit two bursts of generation requests with
   deadlines (interactive jobs in priority class 0, batch in class 1).
   The **elastic** gateway starts with zero replicas, scales spot replicas
   against queue depth (``core/elastic.Provisioner`` + ``core/market``),
   suffers one forced mid-decode spot revocation (whose requests are
   re-enqueued and completed — none lost), and drains back to zero after
   the idle timeout. The **static** baseline pre-provisions the same peak
   replica count on-demand and keeps it up for the whole makespan — the
   classic stranded-capacity strawman. Both run the same virtual-clock
   :class:`~repro.serve.admission.ServiceModel`, so $ cost, deadline-hit
   rate and tokens/sim-second are deterministic and comparable.
2. ``isolation``: identical prompts across tenants produce ZERO prefix-
   cache hits (tenant-scoped namespaces) while a repeat within the tenant
   aliases its cached pages; the audit log holds every allow/deny.
3. ``interactive_burst``: every decode slot is held by a long batch-class
   job when a burst of tight-deadline interactive requests arrives. Three
   runs share the identical arrival trace: **preempt** (deadline-aware
   decode preemption on — each interactive request pauses the
   latest-deadline batch slot, starts immediately, and the victim resumes
   losslessly), **no_preempt** (same tight deadlines, preemption off — the
   policy can only shed them), and **no_preempt_wait** (preemption off,
   interactive deadlines dropped — measures the wait an interactive
   request actually endures when it cannot jump the batch). p99
   interactive TTFT with preemption vs. the wait baseline is the headline;
   the shed count of ``no_preempt`` shows the only alternative under real
   deadlines.
4. ``fleet_routing``: a Zipf-skewed multi-tenant backlog over a static
   3-replica fleet, run under **affinity** routing (replicas advertise
   radix fingerprints of their prefix caches; the router places each
   request where its prefix is already resident) and **blind** round-robin
   on the identical trace. The page pool is tighter than every tenant's
   prefix on every replica, so blind churns the caches while affinity
   partitions tenants into stable residency — fleet tok/sim-s and p99 TTFT
   ratios are the headline. A third run (**disagg**) splits the fleet into
   1 prefill-specialized + 2 decode replicas: admission prefill happens on
   the prefill replica, finished KV pages ship to a decode replica
   (``export_pages``/``import_pages``), and the per-request shipping bytes
   are recorded (and exactly gated — they are a pure layout constant).

5. ``fault_recovery``: three on-demand replicas under an identical scripted
   fault schedule (two revocation notices + one no-warning crash), run as
   **baseline** (no faults — the token-identity oracle), **evacuate**
   (notice-window KV evacuation: noticed replicas ship live/paused KV
   mid-decode to survivors) and **requeue** (evacuation off: notice expires
   into a hard revoke, requests restart from the prompt with backoff).
   Recovered-TTFT ratio requeue/evacuate and goodput ratio evacuate/requeue
   are the headlines; tokens must be identical across all three modes.

6. ``session_resume``: an open-loop trace where a fraction of sessions
   come back after an exponential cold gap (``loadgen`` resume class), run
   **tiered** (a :class:`~repro.serve.kv_store.TieredKVStore` demotes
   finished sessions' pages to HOST, spills to OBJECT under a tiny HOST
   cap, and restores them asynchronously when the resume arrives) vs
   **reprefill** (no store — resumes pay full prefill) on the identical
   trace. Mean resumed TTFT ratio and $/1k resumed tokens (compute +
   storage GB-hours) are the headlines; greedy tokens must be identical
   across both modes (f32 AND an int8-KV leg), proving demote/restore
   preserves page contents bit-exactly.

Results land in ``BENCH_gateway.json`` alongside the CSV rows that
``benchmarks/run.py`` prints. ``--smoke`` runs a one-burst subset for CI
(control-plane breakage, not numbers). Any scenario failure is recorded in
``results["failures"]`` and re-raised after the JSON is written, so a CI
gate can never pass on a half-run bench.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core.elastic import ProvisioningModel, ScalingPolicy
from repro.core.market import SpotMarket
from repro.core.security import PolicyEngine, provision_tenant
from repro.core.clock import VirtualClock
from repro.core.scheduler import ShardedStateStore, StateStore
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, DeadlineCostPolicy,
                         FaultEvent, FaultInjector, JobState,
                         KottaServeGateway, ServiceModel, TieredKVStore,
                         TrafficConfig, generate_trace, run_open_loop)
from repro.serve.loadgen import offered_load

ARCH = "yi-6b"
TENANTS = ("alice", "bob", "carol")
MAX_LEN = 64
SLOTS = 4                       # decode slots per replica
MAX_REPLICAS = 3
PREFIX_LEN = 16                 # per-tenant hot system prompt (2 pages)
BURST_JOBS = 9                  # per burst, round-robin across tenants
BURST_GAP_S = 600.0             # lull between bursts (idle cost shows here)
MAX_NEW = 16
IDLE_TIMEOUT_S = 120.0
PROVISION_DELAY_S = 60.0
SERVICE = ServiceModel(prefill_tok_per_s=2048.0, decode_step_s=0.05)

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_gateway.json"


def _build():
    cfg = get_reduced_config(ARCH).replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _factory(cfg, params, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return lambda: ContinuousBatchingEngine(cfg, params, **kw)


def _security():
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}", data_zones=("public",))
              for t in TENANTS}
    return sec, tokens


def _trace(cfg, bursts: int, jobs_per_burst: int):
    """(arrival_s, tenant, prompt, max_new, deadline_s, priority) rows.

    Each tenant's prompts share that tenant's hot prefix, so same-tenant
    admissions alias cached pages (less fresh prefill -> more deadline
    headroom) while cross-tenant prompts never do.
    """
    rng = np.random.RandomState(42)
    prefixes = {t: rng.randint(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
                for t in TENANTS}
    rows = []
    for b in range(bursts):
        t0 = b * BURST_GAP_S
        for i in range(jobs_per_burst):
            tenant = TENANTS[i % len(TENANTS)]
            tail = rng.randint(0, cfg.vocab_size, size=4 + i % 5).tolist()
            interactive = i % 3 == 0
            rows.append((t0 + i * 2.0, tenant,
                         prefixes[tenant] + tail, MAX_NEW,
                         240.0 if interactive else 3600.0,
                         0 if interactive else 1))
    return rows


def _run_trace(gw, tokens, trace, revoke_once: bool):
    """Submit arrivals on the virtual clock, optionally force one spot
    revocation mid-decode during the second half, then drain."""
    revoked = False
    rids = []
    rounds = 0
    max_rounds = 20_000

    def tick():
        nonlocal rounds
        rounds += 1
        if rounds > max_rounds:                 # fail crisply, not hang CI
            raise RuntimeError(f"trace did not drain in {max_rounds} "
                               f"rounds ({gw.outstanding()} outstanding)")
        gw.step()

    half = trace[len(trace) // 2][0]
    for arrival, tenant, prompt, max_new, deadline_s, prio in trace:
        while gw.clock.now() < arrival:
            tick()
            if (revoke_once and not revoked and gw.clock.now() >= half
                    and gw.replicas()
                    and any(l.emitted > 0
                            for l in gw.replicas()[0].engine._live.values())):
                gw.revoke_replica(gw.replicas()[0].id)
                revoked = True
        rids.append(gw.submit(tokens[tenant], prompt, max_new=max_new,
                              deadline_s=deadline_s, priority=prio,
                              data_zone="public"))
    while gw.outstanding():
        tick()
        if (revoke_once and not revoked and gw.replicas()
                and any(l.emitted > 0
                        for l in gw.replicas()[0].engine._live.values())):
            gw.revoke_replica(gw.replicas()[0].id)
            revoked = True
    # Let the elastic pool idle out so its termination cost is in the bill.
    for _ in range(int(IDLE_TIMEOUT_S / gw.idle_tick_s) + 2):
        gw.step()
    return rids, revoked


def _bench_trace(cfg, params, verbose, results, bursts=2,
                 jobs_per_burst=BURST_JOBS):
    trace = _trace(cfg, bursts, jobs_per_burst)
    out = {}
    wall = {}
    for mode in ("elastic", "static"):
        sec, tokens = _security()
        if mode == "elastic":
            gw = KottaServeGateway(
                _factory(cfg, params), sec,
                scaling=ScalingPolicy.limited(
                    MAX_REPLICAS, market="spot", bid_fraction=0.5,
                    idle_timeout_s=IDLE_TIMEOUT_S),
                market=SpotMarket(seed=0),
                provisioning=ProvisioningModel(
                    base_delay_s=PROVISION_DELAY_S, jitter_s=0.0,
                    volatility_prob=0.0),
                service_model=SERVICE, idle_tick_s=5.0)
        else:
            gw = KottaServeGateway(
                _factory(cfg, params), sec,
                scaling=ScalingPolicy.none(MAX_REPLICAS,
                                           market="on_demand"),
                service_model=SERVICE, idle_tick_s=5.0)
        t0 = time.perf_counter()
        rids, revoked = _run_trace(gw, tokens, trace,
                                   revoke_once=(mode == "elastic"))
        wall[mode] = time.perf_counter() - t0
        m = gw.metrics()
        m["revoked_mid_decode"] = revoked
        m["all_completed_or_shed"] = all(
            gw.jobs[r].status in (JobState.DONE, JobState.SHED)
            for r in rids)
        out[mode] = m

    ratio = out["static"]["cost_usd"] / max(out["elastic"]["cost_usd"],
                                            1e-12)
    results["trace"] = {
        "jobs": len(trace), "tenants": len(TENANTS),
        "elastic": out["elastic"], "static": out["static"],
        "cost_ratio_static_over_elastic": ratio}
    if verbose:
        print(f"\n== gateway: bursty multi-tenant trace ({len(trace)} jobs, "
              f"{len(TENANTS)} tenants, {MAX_REPLICAS} max replicas) ==")
        print(f"{'mode':<9}{'$cost':>9}{'$/1k tok':>10}{'hit%':>7}"
              f"{'sla%':>7}{'shed':>6}{'revoked':>9}{'requeued':>9}"
              f"{'peak':>6}")
        for mode in ("elastic", "static"):
            m = out[mode]
            print(f"{mode:<9}{m['cost_usd']:>9.4f}"
                  f"{m['usd_per_1k_tokens']:>10.4f}"
                  f"{100 * m['deadline_hit_rate']:>6.1f}%"
                  f"{100 * m['sla_rate']:>6.1f}%{m['shed']:>6}"
                  f"{m['revocations']:>9}{m['requeues']:>9}"
                  f"{m['peak_replicas']:>6}")
        print(f"headline: static-OD / elastic-spot cost = {ratio:.1f}x "
              f"(paper: 'up to 16x'); revocation mid-decode lost "
              f"{0 if out['elastic']['all_completed_or_shed'] else '!'}"
              f" requests")
    rows = []
    for mode in ("elastic", "static"):
        m = out[mode]
        rows.append((f"gateway.{mode}", wall[mode] * 1e6 / len(trace),
                     f"cost_usd={m['cost_usd']:.4f};"
                     f"hit_rate={m['deadline_hit_rate']:.2f};"
                     f"sla={m['sla_rate']:.2f};"
                     f"tok_sim_s={m['tok_per_sim_s']:.1f}"))
    rows.append(("gateway.cost_ratio", 0.0, f"static_over_elastic="
                 f"{ratio:.2f}x"))
    return rows


IB_BATCH_MAX_NEW = 40           # long batch-class jobs: hold slots ~2 s
IB_INTER_MAX_NEW = 6
IB_INTER_DEADLINE_S = 0.5       # only an (almost) instant start can meet it
IB_INTER_ARRIVALS = (0.5, 0.9, 1.3, 1.7)
IB_NUM_PAGES = 48               # headroom: paused victims keep pages pinned


def _bench_interactive_burst(cfg, params, verbose, results):
    """p99 interactive TTFT with and without decode preemption.

    All decode slots hold long batch jobs when the interactive burst lands.
    ``preempt``: tight deadlines + preemption — each interactive request is
    infeasible at occupancy, pauses the latest-deadline batch slot (pages
    pinned) and starts immediately; the victim resumes losslessly.
    ``no_preempt``: same deadlines, preemption off — shedding is the
    policy's only move. ``no_preempt_wait``: preemption off and no
    interactive deadlines — the wait such a request endures when it cannot
    jump the batch, which is the TTFT baseline preemption is up against.
    """
    rng = np.random.RandomState(9)
    batch_prompts = [rng.randint(0, cfg.vocab_size, size=12).tolist()
                     for _ in range(SLOTS)]
    inter_prompts = [rng.randint(0, cfg.vocab_size, size=8).tolist()
                     for _ in IB_INTER_ARRIVALS]
    modes = {"preempt": (True, IB_INTER_DEADLINE_S),
             "no_preempt": (False, IB_INTER_DEADLINE_S),
             "no_preempt_wait": (False, None)}
    out = {}
    for mode, (preempt_on, ideadline) in modes.items():
        sec, tokens = _security()
        gw = KottaServeGateway(
            lambda: ContinuousBatchingEngine(
                cfg, params, max_len=MAX_LEN, max_slots=SLOTS,
                num_pages=IB_NUM_PAGES, prefill_chunk=8, decode_chunk=2),
            sec, scaling=ScalingPolicy.none(1, market="on_demand"),
            service_model=SERVICE, idle_tick_s=0.5,
            admission=DeadlineCostPolicy(model=SERVICE, preempt=preempt_on))
        tok = tokens[TENANTS[0]]
        b_rids = [gw.submit(tok, p, max_new=IB_BATCH_MAX_NEW,
                            deadline_s=3600.0, priority=1,
                            data_zone="public") for p in batch_prompts]
        arrivals = sorted(zip(IB_INTER_ARRIVALS, inter_prompts))
        i_rids = []
        rounds = 0
        for arrival, prompt in arrivals:
            while gw.clock.now() < arrival:
                gw.step()
                rounds += 1
                if rounds > 20_000:
                    raise RuntimeError("interactive_burst did not reach "
                                       f"arrival t={arrival}")
            i_rids.append(gw.submit(tok, prompt, max_new=IB_INTER_MAX_NEW,
                                    deadline_s=ideadline, priority=0,
                                    data_zone="public"))
        gw.drain()
        m = gw.metrics()
        m["batch_completed"] = sum(
            1 for r in b_rids if gw.jobs[r].status is JobState.DONE)
        m["interactive_shed"] = sum(
            1 for r in i_rids if gw.jobs[r].status is JobState.SHED)
        m["audit_preempts"] = len(
            [r for r in sec.audit.records() if r.action == "serve:Preempt"])
        out[mode] = m

    p99_pre = out["preempt"]["interactive_p99_ttft_s"]
    p99_wait = out["no_preempt_wait"]["interactive_p99_ttft_s"]
    results["interactive_burst"] = {
        "batch_jobs": SLOTS, "batch_max_new": IB_BATCH_MAX_NEW,
        "interactive_jobs": len(IB_INTER_ARRIVALS),
        "interactive_deadline_s": IB_INTER_DEADLINE_S,
        "preempt": out["preempt"], "no_preempt": out["no_preempt"],
        "no_preempt_wait": out["no_preempt_wait"],
        "ttft_reduction_s": p99_wait - p99_pre,
        "ttft_speedup": p99_wait / max(p99_pre, SERVICE.decode_step_s)}
    if verbose:
        print(f"\n== gateway: interactive burst under full batch occupancy "
              f"({SLOTS} slots, {len(IB_INTER_ARRIVALS)} interactive "
              f"arrivals, deadline {IB_INTER_DEADLINE_S}s) ==")
        print(f"{'mode':<17}{'p99 TTFT':>10}{'i-sla%':>8}{'shed':>6}"
              f"{'preempts':>10}{'resumes':>9}{'wait_s':>8}")
        for mode in modes:
            m = out[mode]
            print(f"{mode:<17}{m['interactive_p99_ttft_s']:>9.2f}s"
                  f"{100 * m['interactive_sla_rate']:>7.1f}%"
                  f"{m['interactive_shed']:>6}{m['preemptions']:>10}"
                  f"{m['resumes']:>9}{m['preempt_wait_s']:>8.2f}")
        print(f"headline: preemption cuts interactive p99 TTFT "
              f"{p99_wait:.2f}s -> {p99_pre:.2f}s "
              f"({results['interactive_burst']['ttft_speedup']:.1f}x); "
              f"without it the same deadlines shed "
              f"{out['no_preempt']['interactive_shed']}/"
              f"{len(IB_INTER_ARRIVALS)} interactive jobs")
    return [("gateway.burst.preempt", p99_pre * 1e6,
             f"p99_ttft_s={p99_pre:.3f};"
             f"preemptions={out['preempt']['preemptions']};"
             f"isla={out['preempt']['interactive_sla_rate']:.2f}"),
            ("gateway.burst.wait", p99_wait * 1e6,
             f"p99_ttft_s={p99_wait:.3f};"
             f"speedup={results['interactive_burst']['ttft_speedup']:.2f}x")]


FLEET_TENANTS = tuple(f"tenant{i}" for i in range(6))
FLEET_PREFIX_LEN = 32           # per-tenant hot system prompt (4 pages)
FLEET_REPLICAS = 3
FLEET_MAX_NEW = 8
FLEET_ZIPF_ALPHA = 1.1          # tenant popularity skew
FLEET_JOBS = 60
FLEET_SMOKE_JOBS = 24
FLEET_ARRIVAL_GAP_S = 0.1       # near-saturation: routing decides who queues
# One decode slot per replica and a page pool that durably caches ~2
# tenants' prefixes, not all 6: placement is an actual choice (an affinity
# winner may be busy) and residency is contended (blind round-robin smears
# all 6 prefixes over every replica and churns them out).
FLEET_SLOTS = 1
FLEET_NUM_PAGES = 24
# Prefill-heavy service point: a fresh 32-token prefix costs 0.5 sim-s
# against an 0.08 sim-s decode, so WHERE a request lands (cached prefix or
# not) dominates fleet throughput — the regime prefix-affinity routing is
# for. Decode-biased workloads are covered by the ``trace`` scenario.
FLEET_SERVICE = ServiceModel(prefill_tok_per_s=64.0, decode_step_s=0.01)


def _fleet_security():
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}", data_zones=("public",))
              for t in FLEET_TENANTS}
    return sec, tokens


def _fleet_trace(cfg, jobs: int):
    """(tenant, prompt) rows: Zipf-skewed tenant choice, per-tenant hot
    prefix + small unique tail. Arrivals are paced every
    ``FLEET_ARRIVAL_GAP_S`` sim-seconds — near the fleet's warm-cache
    service rate, so bad placement (fresh prefill where a cached copy
    exists elsewhere) is what builds queues."""
    rng = np.random.RandomState(1234)
    prefixes = {t: rng.randint(0, cfg.vocab_size,
                               size=FLEET_PREFIX_LEN).tolist()
                for t in FLEET_TENANTS}
    w = 1.0 / np.arange(1, len(FLEET_TENANTS) + 1) ** FLEET_ZIPF_ALPHA
    w /= w.sum()
    rows = []
    for i in range(jobs):
        tenant = FLEET_TENANTS[rng.choice(len(FLEET_TENANTS), p=w)]
        tail = rng.randint(0, cfg.vocab_size, size=2 + i % 5).tolist()
        rows.append((tenant, prefixes[tenant] + tail))
    return rows


def _bench_fleet_routing(cfg, params, verbose, results,
                         jobs: int = FLEET_JOBS):
    """Prefix-affinity routing vs blind round-robin on a Zipf-skewed
    multi-tenant backlog, plus a disaggregated prefill/decode fleet.

    ``affinity`` and ``blind`` run the IDENTICAL trace on identical static
    3-replica fleets; only the router differs. The per-replica page pool is
    deliberately smaller than 6 tenants' hot prefixes plus the active
    working set, so blind round-robin — which smears every tenant across
    every replica — churns the caches while affinity partitions tenants
    into stable residency. ``disagg`` reruns affinity with a 1-prefill +
    2-decode split fleet and reports the KV page-shipping bill per request
    (a pure layout constant: the regression gate pins it exactly).
    """
    trace = _fleet_trace(cfg, jobs)
    out = {}

    def run_mode(mode):
        sec, tokens = _fleet_security()
        kw = dict(max_slots=FLEET_SLOTS, num_pages=FLEET_NUM_PAGES)
        if mode == "disagg":
            gw = KottaServeGateway(
                _factory(cfg, params, role="decode", **kw), sec,
                scaling=ScalingPolicy.none(FLEET_REPLICAS - 1,
                                           market="on_demand"),
                service_model=FLEET_SERVICE, routing="affinity",
                prefill_replicas=1,
                prefill_engine_factory=_factory(cfg, params, role="prefill",
                                                prefill_chunk=16, **kw))
        else:
            gw = KottaServeGateway(
                _factory(cfg, params, **kw), sec,
                scaling=ScalingPolicy.none(FLEET_REPLICAS,
                                           market="on_demand"),
                service_model=FLEET_SERVICE, routing=mode)
        rids = []
        rounds = 0
        for i, (tenant, prompt) in enumerate(trace):
            while gw.clock.now() < i * FLEET_ARRIVAL_GAP_S:
                gw.step()
                rounds += 1
                if rounds > 50_000:
                    raise RuntimeError(f"fleet[{mode}] stalled before "
                                       f"arrival {i}")
            rids.append(gw.submit(tokens[tenant], prompt,
                                  max_new=FLEET_MAX_NEW, priority=0,
                                  data_zone="public"))
        gw.drain()
        m = gw.metrics()
        engs = [gw.replica_engine(e["replica"]) for e in m["per_replica"]]
        cached = sum(e.stats["cached_tokens"] for e in engs)
        fresh = sum(e.stats["prefill_tokens"] for e in engs)
        m["fleet_prefix_hit_rate"] = cached / max(cached + fresh, 1)
        m["fresh_prefill_tokens"] = int(fresh)
        m["page_ship_bytes_per_request"] = (
            m["page_ship_bytes"] / max(m["completed"], 1))
        m["all_done"] = all(gw.jobs[r].status is JobState.DONE for r in rids)
        return m

    for mode in ("affinity", "blind", "disagg"):
        out[mode] = run_mode(mode)
        assert out[mode]["all_done"], f"fleet[{mode}]: not all jobs finished"

    tok_ratio = (out["affinity"]["tok_per_sim_s"]
                 / max(out["blind"]["tok_per_sim_s"], 1e-12))
    ttft_ratio = (out["blind"]["interactive_p99_ttft_s"]
                  / max(out["affinity"]["interactive_p99_ttft_s"], 1e-3))
    results["fleet_routing"] = {
        "jobs": jobs, "tenants": len(FLEET_TENANTS),
        "replicas": FLEET_REPLICAS, "zipf_alpha": FLEET_ZIPF_ALPHA,
        "prefix_len": FLEET_PREFIX_LEN,
        "affinity": out["affinity"], "blind": out["blind"],
        "disagg": out["disagg"],
        "tok_ratio_affinity_over_blind": tok_ratio,
        "ttft_p99_ratio_blind_over_affinity": ttft_ratio,
        "page_ship_bytes_per_request":
            out["disagg"]["page_ship_bytes_per_request"]}
    if verbose:
        print(f"\n== gateway: prefix-affinity fleet routing ({jobs} jobs, "
              f"{len(FLEET_TENANTS)} tenants Zipf {FLEET_ZIPF_ALPHA}, "
              f"{FLEET_REPLICAS} replicas) ==")
        print(f"{'mode':<10}{'tok/sim-s':>11}{'p99 TTFT':>10}{'hit%':>7}"
              f"{'fresh tok':>11}{'ships':>7}{'MB/req':>8}")
        for mode in ("affinity", "blind", "disagg"):
            m = out[mode]
            print(f"{mode:<10}{m['tok_per_sim_s']:>11.1f}"
                  f"{m['interactive_p99_ttft_s']:>9.2f}s"
                  f"{100 * m['fleet_prefix_hit_rate']:>6.1f}%"
                  f"{m['fresh_prefill_tokens']:>11}"
                  f"{m['page_ships']:>7}"
                  f"{m['page_ship_bytes_per_request'] / 1e6:>8.2f}")
        print(f"headline: affinity/blind fleet tok/s = {tok_ratio:.2f}x, "
              f"blind/affinity p99 TTFT = {ttft_ratio:.2f}x; disagg ships "
              f"{out['disagg']['page_ship_bytes_per_request'] / 1e6:.2f} "
              f"MB/request")
    return [("gateway.fleet.affinity",
             out["affinity"]["interactive_p99_ttft_s"] * 1e6,
             f"tok_sim_s={out['affinity']['tok_per_sim_s']:.1f};"
             f"hit={out['affinity']['fleet_prefix_hit_rate']:.2f};"
             f"tok_ratio_vs_blind={tok_ratio:.2f}x"),
            ("gateway.fleet.blind",
             out["blind"]["interactive_p99_ttft_s"] * 1e6,
             f"tok_sim_s={out['blind']['tok_per_sim_s']:.1f};"
             f"hit={out['blind']['fleet_prefix_hit_rate']:.2f}"),
            ("gateway.fleet.disagg",
             out["disagg"]["interactive_p99_ttft_s"] * 1e6,
             f"tok_sim_s={out['disagg']['tok_per_sim_s']:.1f};"
             f"ships={out['disagg']['page_ships']};"
             f"mb_per_req="
             f"{out['disagg']['page_ship_bytes_per_request'] / 1e6:.2f}")]


def _bench_isolation(cfg, params, verbose, results):
    """Tenant-scoped prefix cache: same prompt, zero cross-tenant hits."""
    sec, tokens = _security()
    gw = KottaServeGateway(
        _factory(cfg, params), sec,
        scaling=ScalingPolicy.none(1, market="on_demand"),
        service_model=SERVICE)
    eng = gw.replicas()[0].engine
    prompt = np.random.RandomState(7).randint(
        0, cfg.vocab_size, size=24).tolist()

    gw.submit(tokens["alice"], prompt, max_new=4, data_zone="public")
    gw.drain()
    cold = eng.stats["cached_tokens"]

    gw.submit(tokens["alice"], prompt, max_new=4, data_zone="public")
    gw.drain()
    same = eng.stats["cached_tokens"] - cold

    before = eng.stats["cached_tokens"]
    gw.submit(tokens["bob"], prompt, max_new=4, data_zone="public")
    gw.drain()
    cross = eng.stats["cached_tokens"] - before

    audit_allow = len(sec.audit.records(decision="allow"))
    audit_deny = len(sec.audit.records(decision="deny"))
    results["isolation"] = {
        "prompt_len": len(prompt), "same_tenant_cached_tokens": int(same),
        "cross_tenant_cached_tokens": int(cross),
        "audit_allows": audit_allow, "audit_denies": audit_deny}
    if verbose:
        print(f"\n== gateway: tenant prefix-cache isolation "
              f"({len(prompt)}-token prompt) ==")
        print(f"same-tenant repeat: {same} cached tokens   cross-tenant: "
              f"{cross} cached tokens   audit: {audit_allow} allows / "
              f"{audit_deny} denies")
    assert cross == 0, "cross-tenant prefix hit: isolation broken"
    return [("gateway.isolation", 0.0,
             f"same_tenant_hits={same};cross_tenant_hits={cross}")]


FR_REPLICAS = 3
FR_PREFIX_LEN = 32              # per-tenant hot prefix (4 pages)
FR_MAX_NEW = 24                 # long enough that faults land mid-decode
FR_JOBS = 12
FR_SMOKE_JOBS = 9
FR_ARRIVAL_GAP_S = 0.1
FR_NOTICE_S = 0.5               # scaled-down 2-minute warning: ~1 round
FR_PROVISION_DELAY_S = 2.0
# Prefill-heavy service point (same regime as fleet_routing): restarting a
# request from the prompt costs 0.5+ sim-s of re-prefill, while shipping
# its KV pages costs microseconds of modelled wire time — the gap the
# evacuation path exists to exploit.
FR_SERVICE = ServiceModel(prefill_tok_per_s=64.0, decode_step_s=0.01)
# The reproducible fault schedule: two revocation notices on the lowest-id
# replica (the graceful path under test) bracketing one no-warning crash
# (the requeue path both modes share). Scripted, not seeded — the bench
# must disturb the same requests the same way in every mode.
FR_SCHEDULE = (
    FaultEvent(at_s=0.8, kind="revoke_notice", target=0,
               duration_s=FR_NOTICE_S),
    FaultEvent(at_s=1.5, kind="crash", target=1),
    FaultEvent(at_s=2.2, kind="revoke_notice", target=0,
               duration_s=FR_NOTICE_S),
)


def _bench_fault_recovery(cfg, params, verbose, results,
                          jobs: int = FR_JOBS):
    """Recovery cost of replica loss: notice-window KV evacuation vs
    abort-and-requeue, on the identical scripted fault schedule.

    Three runs share one arrival trace. ``baseline`` sees no faults (the
    token-identity oracle). ``evacuate`` takes the schedule with
    ``evacuate_on_notice`` — noticed replicas ship every live/paused
    request's KV out mid-decode and surviving replicas import them.
    ``requeue`` takes the same schedule with evacuation off — noticed
    replicas decode until the deadline, then die like a crash, and their
    requests restart from the prompt with backoff. Headlines: mean
    recovered TTFT (disturbance -> next decode-slot occupancy) ratio
    requeue/evacuate, and goodput (tok/sim-s) ratio evacuate/requeue.
    Every mode must finish every job with IDENTICAL tokens to the
    undisturbed baseline — greedy decode across an evacuation or a requeue
    is bit-stable, or the whole failure story is moot.
    """
    rng = np.random.RandomState(77)
    prefixes = {t: rng.randint(0, cfg.vocab_size,
                               size=FR_PREFIX_LEN).tolist()
                for t in TENANTS}
    trace = []
    for i in range(jobs):
        tenant = TENANTS[i % len(TENANTS)]
        tail = rng.randint(0, cfg.vocab_size, size=3 + i % 4).tolist()
        trace.append((tenant, prefixes[tenant] + tail))

    def run_mode(mode):
        sec, tokens = _security()
        injector = None if mode == "baseline" \
            else FaultInjector(schedule=FR_SCHEDULE)
        gw = KottaServeGateway(
            _factory(cfg, params), sec,
            scaling=ScalingPolicy.none(FR_REPLICAS, market="on_demand"),
            provisioning=ProvisioningModel(
                base_delay_s=FR_PROVISION_DELAY_S, jitter_s=0.0,
                volatility_prob=0.0),
            service_model=FR_SERVICE, idle_tick_s=0.5,
            evacuate_on_notice=(mode == "evacuate"),
            fault_injector=injector)
        rids = []
        rounds = 0
        for i, (tenant, prompt) in enumerate(trace):
            while gw.clock.now() < i * FR_ARRIVAL_GAP_S:
                gw.step()
                rounds += 1
                if rounds > 50_000:
                    raise RuntimeError(f"fault_recovery[{mode}] stalled "
                                       f"before arrival {i}")
            rids.append(gw.submit(tokens[tenant], prompt,
                                  max_new=FR_MAX_NEW, priority=1,
                                  data_zone="public"))
        gw.drain(max_rounds=50_000)
        assert all(gw.jobs[r].status is JobState.DONE for r in rids), \
            f"fault_recovery[{mode}]: not every job finished"
        if injector is not None:
            assert injector.pending == 0 and not injector.skipped, \
                f"fault_recovery[{mode}]: schedule did not fully land " \
                f"({injector.pending} pending, {len(injector.skipped)} " \
                "skipped)"
        m = gw.metrics()
        m["tokens_by_rid"] = [gw.result(r) for r in rids]
        return m

    out = {mode: run_mode(mode)
           for mode in ("baseline", "evacuate", "requeue")}
    identity = all(
        out["baseline"]["tokens_by_rid"][i]
        == out["evacuate"]["tokens_by_rid"][i]
        == out["requeue"]["tokens_by_rid"][i]
        for i in range(len(trace)))
    for m in out.values():      # token lists verified; keep the JSON lean
        del m["tokens_by_rid"]
    assert identity, "fault_recovery: tokens diverged across recovery modes"
    for mode in ("evacuate", "requeue"):
        assert out[mode]["disturbed_jobs"] > 0, \
            f"fault_recovery[{mode}]: schedule disturbed no jobs"
        assert out[mode]["recovered_jobs"] > 0, \
            f"fault_recovery[{mode}]: no disturbed job recovered"
    assert out["evacuate"]["evacuations"] > 0, \
        "fault_recovery[evacuate]: notice window evacuated nothing"

    ttft_ratio = (out["requeue"]["recovered_ttft_mean_s"]
                  / max(out["evacuate"]["recovered_ttft_mean_s"], 1e-9))
    goodput_ratio = (out["evacuate"]["tok_per_sim_s"]
                     / max(out["requeue"]["tok_per_sim_s"], 1e-12))
    results["fault_recovery"] = {
        "jobs": len(trace), "max_new": FR_MAX_NEW,
        "notice_s": FR_NOTICE_S,
        "schedule": [{"at_s": e.at_s, "kind": e.kind, "target": e.target}
                     for e in FR_SCHEDULE],
        "baseline": out["baseline"], "evacuate": out["evacuate"],
        "requeue": out["requeue"],
        "token_identity": identity,
        "recovered_ttft_ratio_requeue_over_evacuate": ttft_ratio,
        "goodput_ratio_evacuate_over_requeue": goodput_ratio}
    if verbose:
        print(f"\n== gateway: fault recovery ({len(trace)} jobs, "
              f"{len(FR_SCHEDULE)} scripted faults, notice "
              f"{FR_NOTICE_S}s) ==")
        print(f"{'mode':<10}{'rec TTFT':>10}{'tok/sim-s':>11}{'evac':>6}"
              f"{'requeue':>9}{'retries':>9}{'wasted tok':>12}")
        for mode in ("baseline", "evacuate", "requeue"):
            m = out[mode]
            print(f"{mode:<10}{m['recovered_ttft_mean_s']:>9.2f}s"
                  f"{m['tok_per_sim_s']:>11.1f}{m['evacuations']:>6}"
                  f"{m['requeues']:>9}{m['retries']:>9}"
                  f"{m['wasted_decode_tokens']:>12}")
        print(f"headline: requeue/evacuate recovered TTFT = "
              f"{ttft_ratio:.2f}x, evacuate/requeue goodput = "
              f"{goodput_ratio:.2f}x; tokens identical across all modes "
              f"= {identity}")
    return [("gateway.fault.evacuate",
             out["evacuate"]["recovered_ttft_mean_s"] * 1e6,
             f"rec_ttft_s={out['evacuate']['recovered_ttft_mean_s']:.3f};"
             f"evacuations={out['evacuate']['evacuations']};"
             f"ttft_ratio_vs_requeue={ttft_ratio:.2f}x"),
            ("gateway.fault.requeue",
             out["requeue"]["recovered_ttft_mean_s"] * 1e6,
             f"rec_ttft_s={out['requeue']['recovered_ttft_mean_s']:.3f};"
             f"retries={out['requeue']['retries']};"
             f"goodput_ratio={goodput_ratio:.2f}x")]


# ---------------------------------------------------------------------------
# session_resume: tiered KV hierarchy vs re-prefill on cold-gap resumes
# ---------------------------------------------------------------------------
# Prefill-heavy service point (same regime as fleet_routing /
# fault_recovery). TTFT here is queue wait on the virtual clock, so the
# re-prefill tax must surface as *congestion*: the offered fresh-prefill
# load is sized so that re-prefilling every resumed conversation pushes
# the replica past its 64 tok/s prefill budget (queues build, resumed
# TTFT climbs) while tier restores — which re-register the stream as
# cached pages and prefill only the fresh user turn — keep it under.
SR_SERVICE = ServiceModel(prefill_tok_per_s=64.0, decode_step_s=0.01)
SR_SLOTS = 4
# Free pool beyond the ~20 pages the live slots hold is recycled many
# times over inside a cold gap at this arrival rate, so a finished
# session's device copy is churned out and the resume MUST come back
# through the tier store, not the device radix.
SR_NUM_PAGES = 40
SR_MAX_NEW = 6
SR_DURATION_S = 10.0
SR_SMOKE_DURATION_S = 5.0
SR_RATE_RPS = 6.0
SR_RESUME_FRACTION = 0.7
# Short enough that most resumes land while the trace is still offering
# load (an idle fleet admits a re-prefill in the same round and hides the
# tax), long enough for the pool churn above to evict the device copy.
SR_COLD_GAP_S = 2.0
# HOST tier sized to a handful of resident streams: later demotions spill
# earlier ones to OBJECT, so restores exercise both tier depths.
SR_HOST_CAP_BYTES = 48 * 1024


def _sr_store():
    return TieredKVStore(host_capacity_bytes=SR_HOST_CAP_BYTES,
                         host_restore_bytes_per_s=2e8,
                         object_restore_bytes_per_s=2.5e7,
                         object_restore_base_s=0.05)


def _sr_traffic(cfg, duration_s):
    return TrafficConfig(
        duration_s=duration_s, base_rate_rps=SR_RATE_RPS,
        tenants=len(TENANTS), seed=11, vocab_size=cfg.vocab_size,
        # Near-uniform users: sessions are DISTINCT conversations. Heavy
        # Zipf skew would hand the re-prefill baseline the resumed
        # session's whole stream for free off a same-user sibling's
        # device-cached pages, erasing exactly the cost under test.
        zipf_alpha=1.05,
        prefix_tokens=PREFIX_LEN, tail_tokens_min=2, tail_tokens_max=6,
        interactive_deadline_s=600.0, batch_deadline_s=600.0,
        interactive_max_new=SR_MAX_NEW, batch_max_new=SR_MAX_NEW,
        resume_fraction=SR_RESUME_FRACTION, cold_gap_mean_s=SR_COLD_GAP_S,
        resume_tail_tokens=4)


def _bench_session_resume(cfg, params, verbose, results,
                          duration_s=SR_DURATION_S):
    """Resumed-session TTFT and $ with the tiered KV hierarchy vs re-prefill.

    One loadgen trace with ``resume_fraction`` sessions coming back after
    an exponential cold gap, run twice on an identical single-replica
    fleet: ``tiered`` attaches a :class:`TieredKVStore` (finished
    sessions' pages demote to HOST, spill to OBJECT under the deliberately
    tiny HOST cap, and resumes park RESTORE_PENDING on the async restore),
    ``reprefill`` runs bare (resumes pay full prefill). The trace offers
    just over the replica's prefill budget *if* every resume re-prefills —
    so in ``reprefill`` mode queues build and resumed TTFT (queue wait on
    the virtual clock) climbs, while ``tiered`` restores keep the offered
    fresh-token load under budget. Headlines: mean resumed TTFT ratio
    reprefill/tiered and $/1k resumed tokens (compute + storage GB-hours);
    greedy tokens must be identical across both modes for every request,
    or demote/restore corrupted a page. A scripted int8 leg re-checks
    identity with ``kv_cache_dtype="int8"`` engines (scale pages
    demote/restore alongside data pages).
    """
    tc = _sr_traffic(cfg, duration_s)
    trace = generate_trace(tc)
    resumes = sum(1 for a in trace if a.resumed)
    assert resumes > 0, "session_resume trace generated no resumes"

    # Deferred resumes submit at mode-dependent times (the reply must land
    # first), so submission ORDER differs across modes — identity compares
    # by trace position, never by rid order.
    arrival_pos = {id(a): k for k, a in enumerate(trace)}

    def run_mode(store):
        sec, tokens = _security()
        gw = KottaServeGateway(
            _factory(cfg, params, max_slots=SR_SLOTS,
                     num_pages=SR_NUM_PAGES), sec,
            scaling=ScalingPolicy.none(1, market="on_demand"),
            service_model=SR_SERVICE, idle_tick_s=0.1,
            kv_store=store)
        toks = [tokens[t] for t in TENANTS]
        rid_by_pos: dict[int, int] = {}
        resumed_rids: list[int] = []

        def on_submit(a, rid):
            rid_by_pos[arrival_pos[id(a)]] = rid
            if a.resumed:
                resumed_rids.append(rid)

        run_open_loop(gw, toks, trace, max_rounds=100_000,
                      on_submit=on_submit)
        assert len(rid_by_pos) == len(trace), \
            "session_resume: not every arrival was submitted"
        assert all(gw.jobs[r].status is JobState.DONE
                   for r in rid_by_pos.values()), \
            "session_resume: not every job finished"
        m = gw.metrics()
        rttft = [gw.jobs[r].started_at - gw.jobs[r].submitted_at
                 for r in resumed_rids]
        m["resumed_jobs"] = len(resumed_rids)
        m["resumed_ttft_mean_s"] = sum(rttft) / max(len(rttft), 1)
        m["resumed_tokens_out"] = sum(len(gw.jobs[r].tokens)
                                      for r in resumed_rids)
        m["usd_per_1k_resumed_tokens"] = (
            (m["cost_usd"] + m["storage_cost_usd"]) * 1e3
            / max(m["resumed_tokens_out"], 1))
        m["tokens_by_pos"] = [gw.result(rid_by_pos[k])
                              for k in range(len(trace))]
        return m

    out = {"tiered": run_mode(_sr_store()), "reprefill": run_mode(None)}
    identity = (out["tiered"]["tokens_by_pos"]
                == out["reprefill"]["tokens_by_pos"])
    for m in out.values():      # token lists verified; keep the JSON lean
        del m["tokens_by_pos"]
    assert identity, \
        "session_resume: tokens diverged across demote/restore"
    assert out["tiered"]["kv_demotions"] > 0, \
        "session_resume[tiered]: nothing demoted"
    assert out["tiered"]["kv_restores"] > 0, \
        "session_resume[tiered]: no resume came back through the store"

    # int8 leg: one scripted session through demote -> restore -> resume
    # with an int8 KV pool, against an int8 never-demoted oracle. Scale
    # pages ride the payload's content dict; identity must still hold.
    def int8_mode(store):
        sec, tokens = _security()
        # Pool deliberately tight (the scripted churn below must evict the
        # base session's device copy, else the affinity skip serves it
        # from the device radix and no restore happens).
        gw = KottaServeGateway(
            _factory(cfg, params, max_slots=2,
                     num_pages=20, kv_cache_dtype="int8"), sec,
            scaling=ScalingPolicy.none(1, market="on_demand"),
            service_model=SR_SERVICE, idle_tick_s=0.1, kv_store=store)
        tok = tokens[TENANTS[0]]
        rng = np.random.RandomState(23)
        base = rng.randint(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
        r1 = gw.submit(tok, base, max_new=SR_MAX_NEW, data_zone="public")
        gw.drain()
        reply = gw.result(r1)
        # Churn the device pool so the resume cannot hit the device radix.
        for s in range(3):
            gw.submit(tok, rng.randint(0, cfg.vocab_size,
                                       size=PREFIX_LEN).tolist(),
                      max_new=SR_MAX_NEW, data_zone="public")
        gw.drain()
        tail = rng.randint(0, cfg.vocab_size, size=4).tolist()
        r2 = gw.submit(tok, base + reply + tail, max_new=SR_MAX_NEW,
                       data_zone="public")
        gw.drain()
        return reply, gw.result(r2), gw.metrics()

    i8_reply, i8_resume, i8_m = int8_mode(_sr_store())
    i8_reply0, i8_resume0, _ = int8_mode(None)
    int8_identity = i8_reply == i8_reply0 and i8_resume == i8_resume0
    assert int8_identity, "session_resume[int8]: tokens diverged"
    assert i8_m["kv_restores"] >= 1, \
        "session_resume[int8]: resume did not restore through the store"

    ttft_ratio = (out["reprefill"]["resumed_ttft_mean_s"]
                  / max(out["tiered"]["resumed_ttft_mean_s"],
                        SR_SERVICE.decode_step_s))
    results["session_resume"] = {
        "arrivals": len(trace), "resumes": resumes,
        "resume_fraction": SR_RESUME_FRACTION,
        "cold_gap_mean_s": SR_COLD_GAP_S,
        "host_capacity_bytes": SR_HOST_CAP_BYTES,
        "tiered": out["tiered"], "reprefill": out["reprefill"],
        "token_identity": identity, "int8_token_identity": int8_identity,
        "int8_restores": i8_m["kv_restores"],
        "resumed_ttft_ratio_reprefill_over_tiered": ttft_ratio}
    if verbose:
        print(f"\n== gateway: session resume through the tiered KV "
              f"hierarchy ({len(trace)} arrivals, {resumes} resumes, "
              f"cold gap ~{SR_COLD_GAP_S:.0f}s) ==")
        print(f"{'mode':<11}{'res TTFT':>10}{'restores':>9}{'fallb':>7}"
              f"{'demote':>8}{'spill':>7}{'$/1k res tok':>13}"
              f"{'storage $':>11}")
        for mode in ("tiered", "reprefill"):
            m = out[mode]
            spills = (m["kv_store"] or {}).get("spills", 0)
            print(f"{mode:<11}{m['resumed_ttft_mean_s']:>9.3f}s"
                  f"{m['kv_restores']:>9}{m['kv_restore_fallbacks']:>7}"
                  f"{m['kv_demotions']:>8}{spills:>7}"
                  f"{m['usd_per_1k_resumed_tokens']:>13.4f}"
                  f"{m['storage_cost_usd']:>11.2e}")
        print(f"headline: reprefill/tiered resumed TTFT = "
              f"{ttft_ratio:.2f}x; token identity (f32 + int8) = "
              f"{identity and int8_identity}")
    t = out["tiered"]
    return [("gateway.resume.tiered", t["resumed_ttft_mean_s"] * 1e6,
             f"resumed_ttft_s={t['resumed_ttft_mean_s']:.3f};"
             f"restores={t['kv_restores']};"
             f"ttft_ratio_vs_reprefill={ttft_ratio:.2f}x"),
            ("gateway.resume.reprefill",
             out["reprefill"]["resumed_ttft_mean_s"] * 1e6,
             f"resumed_ttft_s="
             f"{out['reprefill']['resumed_ttft_mean_s']:.3f};"
             f"usd_per_1k="
             f"{out['reprefill']['usd_per_1k_resumed_tokens']:.4f}")]


# ---------------------------------------------------------------------------
# saturation: open-loop offered-load sweep + StateStore write wall (Fig-6)
# ---------------------------------------------------------------------------
# One static replica (SLOTS decode slots) swept with open-loop Poisson
# traffic at three offered loads spanning under- and over-saturation.
# Telemetry (audit records, terminal job states, metric snapshots) flushes
# into a StateStore provisioned at SAT_WRITE_CAPACITY writes/s — small
# enough that the top offered load crosses the table's write wall, which a
# ShardedStateStore with the same per-shard capacity then shards past.
SAT_SERVICE = ServiceModel(prefill_tok_per_s=2048.0, decode_step_s=0.05)
SAT_MAX_NEW = 8
SAT_RATES = (2.0, 5.0, 24.0)        # req/s offered: under / near / over
SAT_DURATION_S = 20.0
SAT_SMOKE_DURATION_S = 8.0
SAT_WRITE_CAPACITY = 40.0           # writes/s, per table (and per shard)
SAT_SHARDS = 4
SAT_TENANTS = ("alice", "bob", "carol", "dan")
SAT_SLO = 0.99
SAT_FLUSH_S = 2.0


def _sat_security():
    sec = PolicyEngine(clock=VirtualClock())
    tokens = [provision_tenant(sec, t, f"pw-{t}", data_zones=("public",))
              for t in SAT_TENANTS]
    return sec, tokens


def _sat_point(cfg, params, rate, duration_s, *, store_factory,
               admission_model=None):
    """One offered-load point: fresh fleet, fresh clock, open-loop trace.

    ``store_factory(clock)`` builds the telemetry table (None = no
    telemetry writes); ``admission_model`` overrides the admission
    policy's ServiceModel (the calibrated rerun) while the gateway's
    billing/pump model stays SAT_SERVICE — physics unchanged, beliefs
    updated.
    """
    sec, tokens = _sat_security()
    store = store_factory(sec.clock) if store_factory is not None else None
    gw = KottaServeGateway(
        _factory(cfg, params), sec,
        admission=DeadlineCostPolicy(model=admission_model or SAT_SERVICE),
        scaling=ScalingPolicy.none(1, market="on_demand"),
        service_model=SAT_SERVICE, idle_tick_s=0.05,
        telemetry_store=store, telemetry_flush_s=SAT_FLUSH_S,
        slo_target=SAT_SLO)
    tc = TrafficConfig(
        duration_s=duration_s, base_rate_rps=rate, diurnal_amplitude=0.5,
        diurnal_period_s=duration_s, tenants=len(SAT_TENANTS), seed=7,
        vocab_size=cfg.vocab_size, prefix_tokens=PREFIX_LEN,
        interactive_deadline_s=5.0, batch_deadline_s=10.0,
        interactive_max_new=SAT_MAX_NEW, batch_max_new=SAT_MAX_NEW)
    trace = generate_trace(tc)
    run_open_loop(gw, tokens, trace, max_rounds=100_000)
    m = gw.metrics()            # timing metrics BEFORE the epilogue flush
    gw.flush_telemetry()        # ... which drains the write backlog
    point = {
        "offered_rps": offered_load(trace, tc), "configured_rps": rate,
        "arrivals": len(trace), "completed": m["completed"],
        "shed": m["shed"], "sla_rate": m["sla_rate"],
        "deadline_hit_rate": m["deadline_hit_rate"],
        "p95_latency_s": m["p95_latency_s"],
        "slo_burn_rate": m["slo_burn_rate"],
        "tok_per_sim_s": m["tok_per_sim_s"],
        "sim_seconds": m["sim_seconds"],
        "completed_rps": (m["completed"] / m["sim_seconds"]
                          if m["sim_seconds"] else 0.0),
        "statestore_throttled": gw.stats["statestore_throttled"],
        "store_write_count": store.write_count if store else 0,
        "store_throttled_writes": store.throttled_writes if store else 0,
    }
    return point, gw, trace


def _bench_saturation(cfg, params, verbose, results,
                      duration_s=SAT_DURATION_S):
    single = lambda clock: StateStore(
        clock=clock, write_capacity=SAT_WRITE_CAPACITY)
    sharded = lambda clock: ShardedStateStore(
        SAT_SHARDS, clock=clock, write_capacity=SAT_WRITE_CAPACITY)

    points = []
    top_gw = None
    top_trace = None
    for rate in SAT_RATES:
        point, gw, trace = _sat_point(cfg, params, rate, duration_s,
                                      store_factory=single)
        points.append(point)
        top_gw, top_trace = gw, trace
    sustained = [p["configured_rps"] for p in points
                 if p["sla_rate"] >= SAT_SLO]
    max_sustained = max(sustained) if sustained else 0.0
    assert points[0]["sla_rate"] >= SAT_SLO > points[-1]["sla_rate"], (
        f"sweep must span the saturation wall: sla "
        f"{points[0]['sla_rate']:.3f} .. {points[-1]['sla_rate']:.3f} "
        f"vs target {SAT_SLO}")

    # The write wall: rerun the top offered load against a 4-way sharded
    # table with the SAME per-shard capacity — throttles must drop.
    sharded_point, _, _ = _sat_point(cfg, params, SAT_RATES[-1], duration_s,
                                     store_factory=sharded)
    thr_single = points[-1]["store_throttled_writes"]
    thr_sharded = sharded_point["store_throttled_writes"]
    assert thr_sharded < thr_single, (
        f"sharding must cut StateStore write throttles: "
        f"{thr_sharded} !< {thr_single}")

    # ServiceModel calibration: fitted (measured) vs assumed service rate
    # at the saturated point, then a rerun with the calibrated admission
    # model so feasibility math tracks measured throughput.
    mean_prompt = int(round(sum(len(a.prompt) for a in top_trace)
                            / max(len(top_trace), 1)))
    fitted = points[-1]["completed_rps"]
    assumed = SAT_SERVICE.assumed_req_per_s(mean_prompt, SAT_MAX_NEW, SLOTS)
    calibrated = SAT_SERVICE.calibrated(fitted, prompt_len=mean_prompt,
                                        max_new=SAT_MAX_NEW, slots=SLOTS)
    cal_point, _, _ = _sat_point(cfg, params, SAT_RATES[-1], duration_s,
                                 store_factory=single,
                                 admission_model=calibrated)

    top_gw.registry.collect()
    results["saturation"] = {
        "rates_rps": list(SAT_RATES), "duration_s": duration_s,
        "slots": SLOTS, "replicas": 1, "slo_target": SAT_SLO,
        "write_capacity_per_table": SAT_WRITE_CAPACITY,
        "shards": SAT_SHARDS,
        "points": points,
        "max_sustained_req_s": max_sustained,
        "statestore": {
            "offered_rps": SAT_RATES[-1],
            "throttled_single": thr_single,
            "throttled_sharded": thr_sharded,
            "writes_single": points[-1]["store_write_count"],
            "writes_sharded": sharded_point["store_write_count"],
        },
        "service_model_calibration": {
            "prompt_len": mean_prompt, "max_new": SAT_MAX_NEW,
            "slots": SLOTS,
            "assumed_req_per_s": assumed,
            "fitted_req_per_s": fitted,
            "overhead_factor": calibrated.overhead,
            "assumed_prefill_tok_per_s": SAT_SERVICE.prefill_tok_per_s,
            "assumed_decode_step_s": SAT_SERVICE.decode_step_s,
            "uncalibrated_deadline_hit_rate":
                points[-1]["deadline_hit_rate"],
            "calibrated_deadline_hit_rate":
                cal_point["deadline_hit_rate"],
            "calibrated_point": cal_point,
        },
        "metric_families": top_gw.registry.families(),
    }
    if verbose:
        print(f"\n== gateway: saturation sweep (open loop, 1x{SLOTS} "
              f"slots, {duration_s:.0f}s, store "
              f"{SAT_WRITE_CAPACITY:.0f} w/s) ==")
        print(f"{'offered':>8}{'arrivals':>9}{'done':>6}{'shed':>6}"
              f"{'sla':>7}{'p95':>8}{'burn':>7}{'throttle':>9}")
        for p in points:
            print(f"{p['offered_rps']:>7.1f}/s{p['arrivals']:>9}"
                  f"{p['completed']:>6}{p['shed']:>6}"
                  f"{p['sla_rate']:>7.3f}{p['p95_latency_s']:>7.2f}s"
                  f"{p['slo_burn_rate']:>7.1f}"
                  f"{p['store_throttled_writes']:>9}")
        print(f"max sustained at {SAT_SLO:.0%} deadline-hit: "
              f"{max_sustained:.1f} req/s")
        print(f"write wall at {SAT_RATES[-1]:.0f} req/s: "
              f"{thr_single} throttles -> {thr_sharded} with "
              f"{SAT_SHARDS} shards")
        print(f"service model: assumed {assumed:.2f} req/s, fitted "
              f"{fitted:.2f} req/s (overhead x{calibrated.overhead:.2f}); "
              f"calibrated admission hit-rate "
              f"{points[-1]['deadline_hit_rate']:.3f} -> "
              f"{cal_point['deadline_hit_rate']:.3f}")
    return [("gateway.saturation.sweep", max_sustained,
             f"max_sustained_rps={max_sustained:.1f};"
             f"points={len(points)};"
             f"throttle_drop={thr_single}->{thr_sharded}"),
            ("gateway.saturation.calibration", calibrated.overhead,
             f"assumed_rps={assumed:.2f};fitted_rps={fitted:.2f};"
             f"overhead={calibrated.overhead:.2f}")]


def run(verbose: bool = True, json_path: str | Path | None = JSON_PATH,
        smoke: bool = False):
    cfg, params = _build()
    results: dict = {"arch": ARCH, "slots_per_replica": SLOTS,
                     "max_replicas": MAX_REPLICAS, "smoke": smoke,
                     "failures": []}
    if smoke:
        scenarios = [("trace", lambda: _bench_trace(
            cfg, params, verbose, results, bursts=1, jobs_per_burst=6))]
    else:
        scenarios = [("trace", lambda: _bench_trace(
            cfg, params, verbose, results))]
    scenarios += [
        ("interactive_burst", lambda: _bench_interactive_burst(
            cfg, params, verbose, results)),
        ("fleet_routing", lambda: _bench_fleet_routing(
            cfg, params, verbose, results,
            jobs=FLEET_SMOKE_JOBS if smoke else FLEET_JOBS)),
        ("isolation", lambda: _bench_isolation(cfg, params, verbose,
                                               results)),
        ("fault_recovery", lambda: _bench_fault_recovery(
            cfg, params, verbose, results,
            jobs=FR_SMOKE_JOBS if smoke else FR_JOBS)),
        ("session_resume", lambda: _bench_session_resume(
            cfg, params, verbose, results,
            duration_s=SR_SMOKE_DURATION_S if smoke else SR_DURATION_S)),
        ("saturation", lambda: _bench_saturation(
            cfg, params, verbose, results,
            duration_s=SAT_SMOKE_DURATION_S if smoke else SAT_DURATION_S)),
    ]
    rows = []
    for name, fn in scenarios:
        # Every scenario is attempted (one failure must not hide the rest),
        # but a failed scenario fails the WHOLE bench after the JSON lands:
        # the CI regression gate must never read a half-run as healthy.
        try:
            rows.extend(fn())
        except Exception as e:                      # noqa: BLE001
            results["failures"].append(f"{name}: {type(e).__name__}: {e}")
            if verbose:
                print(f"\n!! scenario {name} FAILED: {e}")
    if json_path is not None:
        Path(json_path).write_text(json.dumps(results, indent=2) + "\n")
        if verbose:
            print(f"\nwrote {json_path}")
    if results["failures"]:
        raise RuntimeError(
            f"{len(results['failures'])} gateway bench scenario(s) failed: "
            + "; ".join(results["failures"]))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one-burst subset, tiny shapes (CI control-plane "
                         "gate)")
    ap.add_argument("--json", default=None,
                    help="results path (default: BENCH_gateway.json, or "
                         "BENCH_gateway.smoke.json with --smoke)")
    args = ap.parse_args()
    path = args.json or (JSON_PATH.with_suffix(".smoke.json") if args.smoke
                         else JSON_PATH)
    run(smoke=args.smoke, json_path=path)
