"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os


def load(dryrun_dir: str = "results/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        c["_tag"] = os.path.basename(path).split("__")[-1].replace(
            ".json", "")
        c["_tag"] = "" if c["_tag"] in ("single", "multi") else c["_tag"]
        cells.append(c)
    return cells


def fmt(x):
    return f"{x:.2e}"


def roofline_markdown(dryrun_dir: str = "results/dryrun") -> str:
    cells = load(dryrun_dir)
    base = [c for c in cells if not c["_tag"]]
    lines = ["| arch | shape | mesh | compute s | memory s | mem.fused s | "
             "collective s | bottleneck | useful | frac | fits | what would move the bottleneck |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    advice = {
        ("memory_s", True): "Pallas-fused tiles (mem.fused col) then microbatching",
        ("memory_s", False): "Pallas-fused tiles; KV/state already light",
        ("collective_s", True): "remat=dots (fewer FSDP regather passes) / TP-only params",
        ("collective_s", False): "sequence-shard KV cache; batch co-location",
        ("compute_s", True): "block-triangular causal schedule (-2x attn flops)",
        ("compute_s", False): "larger per-chip batch",
    }
    for c in sorted(base, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — |"
                         f" — | — | SKIP | — | — | — | {c['reason']} |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} |"
                         f" ERROR {c['error'][:60]} |||||||||")
            continue
        r, m = c["roofline"], c["memory"]
        is_train = c["shape"].startswith("train")
        tip = advice.get((r["bottleneck"], is_train), "")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {fmt(r['compute_s'])} "
            f"| {fmt(r['memory_s'])} | {fmt(r.get('memory_fused_s', 0))} "
            f"| {fmt(r['collective_s'])} | {r['bottleneck'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {'yes' if m['fits_hbm'] else 'NO'} | {tip} |")
    return "\n".join(lines)


def dryrun_markdown(dryrun_dir: str = "results/dryrun") -> str:
    cells = load(dryrun_dir)
    base = [c for c in cells if not c["_tag"]]
    ok = [c for c in base if c["status"] == "ok"]
    lines = ["| arch | shape | mesh | compile s | GiB/dev | fits | "
             "collectives (per-device wire GB: ag/ar/rs/a2a/cp) |",
             "|---|---|---|---|---|---|---|"]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        m, h = c["memory"], c["hlo"]
        co = h["collective_by_op"]
        cs = "/".join(f"{co.get(k, 0) / 1e9:.1f}" for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                     f"| {c['compile_s']:.1f} "
                     f"| {m['per_device_total'] / 2**30:.2f} "
                     f"| {'yes' if m['fits_hbm'] else 'NO'} | {cs} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(dryrun_markdown())
    print()
    print(roofline_markdown())
